#!/usr/bin/env python3
"""Bench-regression guard for BENCH_kernels.json / BENCH_serve.json.

Compares the current kernel-bench dump against the previous CI run's
artifact and fails when any case's throughput regressed by more than
the allowed fraction. Correctness gates (``eps_ok``) in the *current*
dump fail hard regardless of the baseline.

With ``--serve-prev``/``--serve-cur`` it additionally guards the
``mixed_priority`` scenario of BENCH_serve.json: per model, the
**interactive** lane's ``wait_p95`` (the serving-latency promise of the
priority scheduler) must not grow by more than the allowed fraction
over the baseline, and lane conservation (``served == admitted``) in
the current dump fails hard regardless of any baseline. The
``replica_scaling`` scenario is guarded the same way: per model and
replica count, the cluster's ``tokens_per_s`` must not drop by more
than the allowed fraction vs the baseline scale with the same replica
count, and request conservation (``served == requests``) fails hard.
The ``hot_traffic`` scenario (traffic-aware placement) is guarded too:
per model and arm, ``tokens_per_s`` must not drop by more than the
allowed fraction vs the baseline, and two correctness gates in the
current dump fail hard regardless of any baseline —
``shed_disarmed_identical`` must be true (a disarmed shed policy is a
byte-identical no-op), and every non-shedding arm must conserve
admissions (``served == admitted``).

The ``drift_soak`` recovery arms (router calibration, issue 9) are
guarded when present: on a calibration-armed dump the calibrate arms
must report standing corrections (``calibrated_experts == 0`` with
calibration enabled under drift fails hard), the full escalation ladder
must absorb at least as much deviation as calibrate-only
(``calibrate_migrate.deviation_absorbed >=
calibrate_only.deviation_absorbed``), calibration must spare migration
budget (``calibrate_migrate.migrations < migrate_only.migrations``),
and every standing correction must sit within the dump's
``promote_gate``. Against a baseline with arms, the deviation recovered
per unit maintenance wall time (``recovery_per_maint_s``) of each
calibrate arm must not drop by more than the allowed fraction.

With ``--profiles-prev``/``--profiles-cur`` it also guards
BENCH_profiles.json (the device-profile stress matrix): per model and
profile, the selection-rule **predictiveness** (Spearman ρ between
MaxNNScore and measured degradation) must not drop below the baseline
by more than the allowed fraction (absolute, since ρ lives in [-1, 1]).
Two correctness gates in the *current* dump fail hard regardless of any
baseline: every matrix row must conserve requests (``served ==
requests``), and the ``worst-case`` profile must exercise the promote
path (≥ 1 migration summed over its rows).

Warn-only when a baseline file is missing (first run on a repo whose
trajectory is still empty) or a case has no counterpart — CI shared
runners also make timing noisy, which is why the default threshold is a
generous 25%. A missing *current* serve or profiles dump is also
warn-only: those suites legitimately skip when the artifact tree is
absent.

Usage:
    python3 scripts/bench_guard.py PREV.json CUR.json \
        [--serve-prev PREV_SERVE.json --serve-cur CUR_SERVE.json] \
        [--profiles-prev PREV_PROFILES.json --profiles-cur CUR_PROFILES.json] \
        [--max-regression 0.25]

Exit codes: 0 ok / baseline missing, 1 regression or correctness gate.
"""

import argparse
import json
import os
import sys

# throughput-style metrics to guard, per case kind (higher = better)
GUARDED = ["items_per_s", "speedup_blocked", "speedup_parallel"]


def case_key(case):
    mid = case.get("k", case.get("d", 0))
    return (case.get("kind", "?"), case.get("n", 0), mid, case.get("m", 0))


def load_cases(path):
    with open(path) as f:
        dump = json.load(f)
    return {case_key(c): c for c in dump.get("cases", [])}


def serve_lanes(path):
    """{model: {lane_name: lane_obj}} for every mixed_priority block."""
    with open(path) as f:
        dump = json.load(f)
    out = {}
    for entry in dump.get("models", []):
        mp = entry.get("mixed_priority")
        if mp is None:
            continue
        out[entry.get("model", "?")] = {
            lane.get("lane", "?"): lane for lane in mp.get("lanes", [])
        }
    return out


def serve_scales(path):
    """{model: {replicas: scale_obj}} for every replica_scaling block."""
    with open(path) as f:
        dump = json.load(f)
    out = {}
    for entry in dump.get("models", []):
        rs = entry.get("replica_scaling")
        if rs is None:
            continue
        out[entry.get("model", "?")] = {
            int(s.get("replicas", 0)): s for s in rs.get("scales", [])
        }
    return out


def guard_replica_scaling(prev_path, cur_path, max_regression):
    """Failures for the replica_scaling serve scenario (see module doc)."""
    failures = []
    cur = serve_scales(cur_path)
    if not cur:
        print(f"replica guard: {cur_path} has no replica_scaling blocks — skipped")
        return failures

    # conservation is a correctness gate, baseline or not: every request
    # submitted to the cluster must have completed by shutdown
    for model, scales in cur.items():
        for n, scale in scales.items():
            if scale.get("served") != scale.get("requests"):
                failures.append(
                    f"{model}@{n} replicas: served {scale.get('served')} != "
                    f"requests {scale.get('requests')} — requests lost")

    if not os.path.exists(prev_path):
        print(f"replica guard: no baseline at {prev_path} — warn-only first "
              f"run ({len(cur)} model(s) recorded)")
        return failures

    prev = serve_scales(prev_path)
    compared = 0
    for model, scales in prev.items():
        for n, scale in scales.items():
            cur_scale = cur.get(model, {}).get(n)
            if cur_scale is None:
                print(f"warn: no replica_scaling scale to compare for {model}@{n}")
                continue
            old = float(scale.get("tokens_per_s", 0.0))
            new = float(cur_scale.get("tokens_per_s", 0.0))
            if old <= 0:
                continue
            compared += 1
            drop = (old - new) / old
            regressed = drop > max_regression
            status = "FAIL" if regressed else "ok"
            print(f"{status:>4} {model}@{n} replicas tokens_per_s: "
                  f"{old:.3g} -> {new:.3g} ({-drop * 100:+.1f}%)")
            if regressed:
                failures.append(
                    f"{model}@{n} replicas: cluster tokens_per_s regressed "
                    f"{drop * 100:.1f}% (> {max_regression * 100:.0f}% allowed)")
    print(f"replica guard: {compared} scale(s) compared")
    return failures


def hot_traffic_entries(path):
    """{model: hot_traffic_obj} for every hot_traffic block."""
    with open(path) as f:
        dump = json.load(f)
    out = {}
    for entry in dump.get("models", []):
        ht = entry.get("hot_traffic")
        if ht is not None:
            out[entry.get("model", "?")] = ht
    return out


# hot_traffic arms whose tokens_per_s is guarded against the baseline;
# overload/overload_shed are deliberately excluded (the flood pattern
# is queue-bound, so its throughput is a property of the workload, not
# the engine)
HOT_ARMS = ["baseline", "traffic_aware"]


def guard_hot_traffic(prev_path, cur_path, max_regression):
    """Failures for the hot_traffic serve scenario (see module doc)."""
    failures = []
    cur = hot_traffic_entries(cur_path)
    if not cur:
        print(f"hot-traffic guard: {cur_path} has no hot_traffic blocks — skipped")
        return failures

    for model, ht in cur.items():
        # gate 1: a disarmed shed policy must be a byte-identical no-op
        if ht.get("shed_disarmed_identical") is not True:
            failures.append(
                f"{model}: shed_disarmed_identical is "
                f"{ht.get('shed_disarmed_identical')!r} — a disarmed ShedPolicy "
                f"changed serving output")
        # gate 2: without shedding, every admitted request is served
        for arm in ["baseline", "traffic_aware", "overload", "overload_shed"]:
            obj = ht.get(arm)
            if obj is None:
                failures.append(f"{model}: hot_traffic arm '{arm}' missing")
                continue
            if obj.get("served") != obj.get("admitted"):
                failures.append(
                    f"{model}/{arm}: served {obj.get('served')} != admitted "
                    f"{obj.get('admitted')} — requests lost")

    if not os.path.exists(prev_path):
        print(f"hot-traffic guard: no baseline at {prev_path} — warn-only "
              f"first run ({len(cur)} model(s) recorded)")
        return failures

    prev = hot_traffic_entries(prev_path)
    compared = 0
    for model, ht in prev.items():
        cur_ht = cur.get(model)
        if cur_ht is None:
            print(f"warn: no hot_traffic block to compare for {model}")
            continue
        for arm in HOT_ARMS:
            old = float(ht.get(arm, {}).get("tokens_per_s", 0.0))
            new = float(cur_ht.get(arm, {}).get("tokens_per_s", 0.0))
            if old <= 0:
                continue
            compared += 1
            drop = (old - new) / old
            regressed = drop > max_regression
            status = "FAIL" if regressed else "ok"
            print(f"{status:>4} {model}/{arm} tokens_per_s: "
                  f"{old:.3g} -> {new:.3g} ({-drop * 100:+.1f}%)")
            if regressed:
                failures.append(
                    f"{model}/{arm}: hot_traffic tokens_per_s regressed "
                    f"{drop * 100:.1f}% (> {max_regression * 100:.0f}% allowed)")
    print(f"hot-traffic guard: {compared} arm(s) compared")
    return failures


def drift_soak_entries(path):
    """{model: drift_soak_obj} for every drift_soak block."""
    with open(path) as f:
        dump = json.load(f)
    out = {}
    for entry in dump.get("models", []):
        ds = entry.get("drift_soak")
        if ds is not None:
            out[entry.get("model", "?")] = ds
    return out


# drift-recovery arms whose recovery_per_maint_s is guarded against the
# baseline; no_maintenance/migrate_only are excluded (no_maintenance
# recovers nothing by construction, migrate_only's recovery is already
# pinned by the flat drift_soak gates)
RECOVERY_ARMS = ["calibrate_only", "calibrate_migrate"]


def guard_drift_recovery(prev_path, cur_path, max_regression):
    """Failures for the drift_soak recovery arms (see module doc)."""
    failures = []
    cur = drift_soak_entries(cur_path)
    armed = {m: ds for m, ds in cur.items() if ds.get("arms")}
    if not armed:
        print(f"drift-recovery guard: {cur_path} has no drift_soak arms — "
              f"skipped (bench run without --maint-calibrate?)")
        return failures

    for model, ds in armed.items():
        arms = ds["arms"]
        missing = [a for a in ["no_maintenance", "calibrate_only",
                               "calibrate_migrate", "migrate_only"]
                   if a not in arms]
        if missing:
            failures.append(f"{model}: drift_soak arms missing {missing}")
            continue
        cal_only, cal_mig = arms["calibrate_only"], arms["calibrate_migrate"]
        mig_only = arms["migrate_only"]
        gate = float(ds.get("promote_gate", 0.0))

        # gate 1: calibration enabled under aggressive drift must fit
        # standing corrections — 0 means the tier silently did nothing
        for name, arm in [("calibrate_only", cal_only),
                          ("calibrate_migrate", cal_mig)]:
            if int(arm.get("calibrated_experts", 0)) < 1:
                failures.append(
                    f"{model}/{name}: calibrated_experts=0 with calibration "
                    f"enabled under drift — the calibrate tier never engaged")
        # gate 2: the full ladder absorbs at least what calibrate-only does
        if float(cal_mig.get("deviation_absorbed", 0.0)) < \
                float(cal_only.get("deviation_absorbed", 0.0)):
            failures.append(
                f"{model}: calibrate_migrate absorbed "
                f"{cal_mig.get('deviation_absorbed')} < calibrate_only's "
                f"{cal_only.get('deviation_absorbed')}")
        # gate 3: calibration must spare migration budget (strict — the
        # issue-9 acceptance criterion)
        if int(cal_mig.get("migrations", 0)) >= int(mig_only.get("migrations", 0)):
            failures.append(
                f"{model}: calibrate_migrate spent {cal_mig.get('migrations')} "
                f"migrations, not fewer than migrate_only's "
                f"{mig_only.get('migrations')}")
        # gate 4: standing corrections sit within the promote gate
        if float(cal_mig.get("calibration_residual", 0.0)) > gate + 1e-9:
            failures.append(
                f"{model}/calibrate_migrate: calibration residual "
                f"{cal_mig.get('calibration_residual')} exceeds the promote "
                f"gate {gate}")

    if not os.path.exists(prev_path):
        print(f"drift-recovery guard: no baseline at {prev_path} — warn-only "
              f"first run ({len(armed)} model(s) recorded)")
        return failures

    prev = drift_soak_entries(prev_path)
    compared = 0
    for model, ds in prev.items():
        arms, cur_arms = ds.get("arms"), armed.get(model, {}).get("arms")
        if not arms or not cur_arms:
            continue
        for arm in RECOVERY_ARMS:
            old = float(arms.get(arm, {}).get("recovery_per_maint_s", 0.0))
            new = float(cur_arms.get(arm, {}).get("recovery_per_maint_s", 0.0))
            if old <= 0:
                continue
            compared += 1
            drop = (old - new) / old
            regressed = drop > max_regression
            status = "FAIL" if regressed else "ok"
            print(f"{status:>4} {model}/{arm} recovery_per_maint_s: "
                  f"{old:.3g} -> {new:.3g} ({-drop * 100:+.1f}%)")
            if regressed:
                failures.append(
                    f"{model}/{arm}: deviation recovered per maintenance "
                    f"second regressed {drop * 100:.1f}% "
                    f"(> {max_regression * 100:.0f}% allowed)")
    print(f"drift-recovery guard: {compared} arm(s) compared")
    return failures


def guard_serve(prev_path, cur_path, max_regression):
    """Failures for the mixed_priority serve scenario (see module doc)."""
    failures = []
    if not os.path.exists(cur_path):
        # the serve suite skips without an artifact tree — not an error
        print(f"serve guard: current dump {cur_path} missing — skipped")
        return failures
    cur = serve_lanes(cur_path)
    if not cur:
        print(f"serve guard: {cur_path} has no mixed_priority blocks — skipped")
        return failures

    # conservation is a correctness gate, baseline or not: every
    # admitted request must have been served by shutdown
    for model, lanes in cur.items():
        for name, lane in lanes.items():
            if lane.get("served") != lane.get("admitted"):
                failures.append(
                    f"{model}/{name}: served {lane.get('served')} != "
                    f"admitted {lane.get('admitted')} — requests lost")

    if not os.path.exists(prev_path):
        print(f"serve guard: no baseline at {prev_path} — warn-only first run "
              f"({len(cur)} model(s) recorded)")
        return failures

    prev = serve_lanes(prev_path)
    compared = 0
    for model, lanes in prev.items():
        lane = lanes.get("interactive")
        cur_lane = cur.get(model, {}).get("interactive")
        if lane is None or cur_lane is None:
            print(f"warn: no interactive mixed_priority lane to compare for {model}")
            continue
        old, new = float(lane.get("wait_p95", 0.0)), float(cur_lane.get("wait_p95", 0.0))
        compared += 1
        # latency: higher is worse — guard the relative growth, with a
        # one-tick absolute dead-band so sub-tick wiggles on a tiny
        # baseline (0 → 0.5 ticks) can't fail the build
        growth = (new - old) / max(old, 1.0)
        regressed = growth > max_regression and (new - old) > 1.0
        status = "FAIL" if regressed else "ok"
        print(f"{status:>4} {model} interactive wait_p95: {old:.3g} -> {new:.3g} "
              f"({growth * 100:+.1f}%)")
        if regressed:
            failures.append(
                f"{model}: interactive wait_p95 regressed {growth * 100:.1f}% "
                f"(> {max_regression * 100:.0f}% allowed)")
    print(f"serve guard: {compared} model(s) compared")
    return failures


def profile_entries(path):
    """{model: {profile_name: profile_obj}} from BENCH_profiles.json."""
    with open(path) as f:
        dump = json.load(f)
    out = {}
    for entry in dump.get("models", []):
        out[entry.get("model", "?")] = {
            p.get("profile", "?"): p for p in entry.get("profiles", [])
        }
    return out


def guard_profiles(prev_path, cur_path, max_regression):
    """Failures for the device-profile stress matrix (see module doc)."""
    failures = []
    if not os.path.exists(cur_path):
        # the profiles suite skips without an artifact tree — not an error
        print(f"profile guard: current dump {cur_path} missing — skipped")
        return failures
    cur = profile_entries(cur_path)
    if not cur:
        print(f"profile guard: {cur_path} has no profile blocks — skipped")
        return failures

    # correctness gates, baseline or not: conservation per matrix row,
    # and the worst-case profile must actually promote something
    for model, profiles in cur.items():
        for name, prof in profiles.items():
            migrations = 0
            for row in prof.get("rows", []):
                migrations += int(row.get("migrations", 0))
                if row.get("served") != row.get("requests"):
                    failures.append(
                        f"{model}/{name} (gamma={row.get('gamma')}, "
                        f"every={row.get('maintenance_every_batches')}): served "
                        f"{row.get('served')} != requests {row.get('requests')} "
                        f"— requests lost")
            if name == "worst-case" and migrations < 1:
                failures.append(
                    f"{model}/worst-case: 0 migrations across the matrix — "
                    f"the promote path was never exercised")

    if not os.path.exists(prev_path):
        print(f"profile guard: no baseline at {prev_path} — warn-only first "
              f"run ({len(cur)} model(s) recorded)")
        return failures

    prev = profile_entries(prev_path)
    compared = 0
    for model, profiles in prev.items():
        for name, prof in profiles.items():
            cur_prof = cur.get(model, {}).get(name)
            if cur_prof is None:
                print(f"warn: no profile block to compare for {model}/{name}")
                continue
            old = float(prof.get("predictiveness", 0.0))
            new = float(cur_prof.get("predictiveness", 0.0))
            compared += 1
            # ρ lives in [-1, 1]: guard the absolute drop, not a ratio
            drop = old - new
            regressed = drop > max_regression
            status = "FAIL" if regressed else "ok"
            print(f"{status:>4} {model}/{name} predictiveness: "
                  f"{old:.3f} -> {new:.3f} ({-drop:+.3f})")
            if regressed:
                failures.append(
                    f"{model}/{name}: selection predictiveness dropped "
                    f"{drop:.3f} (> {max_regression:.2f} allowed)")
    print(f"profile guard: {compared} profile(s) compared")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("prev", help="baseline BENCH_kernels.json (previous run)")
    ap.add_argument("cur", help="current BENCH_kernels.json")
    ap.add_argument("--serve-prev", help="baseline BENCH_serve.json (previous run)")
    ap.add_argument("--serve-cur", help="current BENCH_serve.json")
    ap.add_argument("--profiles-prev",
                    help="baseline BENCH_profiles.json (previous run)")
    ap.add_argument("--profiles-cur", help="current BENCH_profiles.json")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed fractional drop per guarded metric")
    args = ap.parse_args()

    serve_failures = []
    if args.serve_cur:
        serve_failures = guard_serve(args.serve_prev or "", args.serve_cur,
                                     args.max_regression)
        if os.path.exists(args.serve_cur):
            serve_failures += guard_replica_scaling(
                args.serve_prev or "", args.serve_cur, args.max_regression)
            serve_failures += guard_hot_traffic(
                args.serve_prev or "", args.serve_cur, args.max_regression)
            serve_failures += guard_drift_recovery(
                args.serve_prev or "", args.serve_cur, args.max_regression)
    if args.profiles_cur:
        serve_failures += guard_profiles(args.profiles_prev or "",
                                         args.profiles_cur, args.max_regression)

    if not os.path.exists(args.cur):
        print(f"bench guard: current dump {args.cur} missing", file=sys.stderr)
        return 1
    cur = load_cases(args.cur)

    failures = list(serve_failures)
    # correctness gates are not perf numbers: a false fails regardless
    # of any baseline (docs/BENCHMARKS.md §Comparing runs)
    for key, case in cur.items():
        if case.get("eps_ok") is False:
            failures.append(f"{key}: eps_ok=false — kernel no longer matches the scalar reference")

    if not os.path.exists(args.prev):
        print(f"bench guard: no baseline at {args.prev} — warn-only first run "
              f"({len(cur)} current cases recorded)")
        for f in failures:
            print(f"FAIL {f}", file=sys.stderr)
        return 1 if failures else 0

    prev = load_cases(args.prev)
    compared = 0
    for key, pc in prev.items():
        cc = cur.get(key)
        if cc is None:
            print(f"warn: case {key} disappeared from the current dump")
            continue
        for metric in GUARDED:
            if metric not in pc or metric not in cc:
                continue
            old, new = float(pc[metric]), float(cc[metric])
            if old <= 0:
                continue
            drop = (old - new) / old
            compared += 1
            status = "FAIL" if drop > args.max_regression else "ok"
            print(f"{status:>4} {key} {metric}: {old:.3g} -> {new:.3g} "
                  f"({-drop * 100:+.1f}%)")
            if drop > args.max_regression:
                failures.append(
                    f"{key} {metric} regressed {drop * 100:.1f}% "
                    f"(> {args.max_regression * 100:.0f}% allowed)")

    print(f"bench guard: {compared} metrics compared, {len(failures)} failure(s)")
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
