#!/usr/bin/env python3
"""Generate the checked-in device-profile fixtures.

Writes two files under python/tests/fixtures/:

- profile_golden.json — a tiny one-layer model (weights drawn from the
  mirrored Prng stream, seed 42) with the sentinel-probe deviation of
  every preset profile at a fixed clock. Consumed by the Rust
  integration test `profile_golden_deviations_within_tolerance` and
  re-verified by tests/test_profile_mirror.py: any accidental change to
  the Prng, the fnv1a tile addressing, a model's loop order, or the
  probe math on either side of the language boundary shows up as a
  deviation mismatch.

- spearman_fuzz.json — ≥ 200 random (xs, ys, rho) cases through the
  bit-exact Spearman port, consumed by the Rust test
  `spearman_matches_python_mirror_fixture` at 1e-12.

Deterministic: re-running reproduces both files byte-for-byte.
"""

import json
import os
import random
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "python", "tests"))

import mirror_profile as mp  # noqa: E402

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "..", "python", "tests", "fixtures")

GOLDEN = {
    "d": 8,
    "m": 6,
    "rows": 4,
    "seed": 9,
    "experts": 2,
    "elapsed_tokens": 4096,
}


def draw_experts(d, m, n_experts):
    """The Rust test's weight stream: Prng(42), up → gate → down."""
    import numpy as np

    rng = mp.Prng(42)

    def draw(length):
        return np.array(
            [rng.gaussian_f32() * np.float32(0.3) for _ in range(length)], np.float32
        )

    return [
        {"up": draw(d * m), "gate": draw(d * m), "down": draw(m * d)}
        for _ in range(n_experts)
    ]


def golden_fixture():
    d, m = GOLDEN["d"], GOLDEN["m"]
    rows, seed = GOLDEN["rows"], GOLDEN["seed"]
    clock = mp.Clock(
        elapsed_tokens=GOLDEN["elapsed_tokens"],
        birth_tokens=0,
        cycle=GOLDEN["elapsed_tokens"],
    )
    experts = draw_experts(d, m, GOLDEN["experts"])
    x = mp.sentinel(rows, d, seed)
    profiles = []
    for name in ["ideal", "pcm-drift", "reram-noisy", "adc-limited", "worst-case"]:
        models = mp.preset(name)
        deviations = []
        for e, host in enumerate(experts):
            want = mp.gated_mlp(x, host["up"], host["gate"], host["down"], rows, d, m)
            up = host["up"].copy()
            gate = host["gate"].copy()
            down = host["down"].copy()
            mp.perturb_matrix(models, up, d, m, mp.Site(0, e, 0), clock)
            mp.perturb_matrix(models, gate, d, m, mp.Site(0, e, 1), clock)
            mp.perturb_matrix(models, down, m, d, mp.Site(0, e, 2), clock)
            got = mp.gated_mlp(x, up, gate, down, rows, d, m)
            deviations.append(mp.probe_deviation(got, want))
        profiles.append({"profile": name, "deviations": deviations})
    return dict(GOLDEN, profiles=profiles)


def spearman_fixture(n_cases=220, seed=0x5EED):
    rng = random.Random(seed)
    cases = []
    for i in range(n_cases):
        n = rng.randint(2, 40)
        xs = [rng.uniform(-10.0, 10.0) for _ in range(n)]
        if i % 4 == 0:
            # exercise the stable tie-break: duplicate some values
            for _ in range(max(1, n // 4)):
                a, b = rng.randrange(n), rng.randrange(n)
                xs[a] = xs[b]
        if i % 7 == 0:
            ys = [x * rng.choice([-2.0, 3.0]) + rng.uniform(-0.1, 0.1) for x in xs]
        else:
            ys = [rng.uniform(-5.0, 5.0) for _ in range(n)]
        cases.append({"xs": xs, "ys": ys, "rho": mp.spearman(xs, ys)})
    return {"cases": cases}


def main():
    os.makedirs(FIXTURE_DIR, exist_ok=True)
    golden = golden_fixture()
    with open(os.path.join(FIXTURE_DIR, "profile_golden.json"), "w") as f:
        json.dump(golden, f, indent=1)
        f.write("\n")
    fuzz = spearman_fixture()
    with open(os.path.join(FIXTURE_DIR, "spearman_fuzz.json"), "w") as f:
        json.dump(fuzz, f)
        f.write("\n")
    for p in golden["profiles"]:
        devs = ", ".join(f"{v:.4f}" for v in p["deviations"])
        print(f"{p['profile']:>12}: [{devs}]")
    print(f"wrote {len(fuzz['cases'])} spearman fuzz cases")


if __name__ == "__main__":
    main()
