#!/usr/bin/env python3
"""Determinism lint pass over rust/src.

The serving path must be replayable: same requests, same placement,
same outputs, run over run. That dies quietly — someone iterates a
HashMap in a planning loop, or keys a decision off wall-clock time —
so this script greps the Rust tree for the nondeterminism sources the
type system cannot see and fails CI on new ones:

- ``wallclock``   — `Instant` / `SystemTime` outside the whitelist of
                    files that legitimately measure wall time (metrics
                    accounting, benches, the CLI driver).
- ``hash-iter``   — `HashMap` / `HashSet` anywhere in the dispatch and
                    planning modules (`moe/`, `coordinator/`), where
                    iteration order would leak into routing, placement,
                    or batch composition. Use `BTreeMap` / `Vec` there,
                    or sort before iterating and allow the line.
- ``extern-rng``  — any RNG besides the repo's own deterministic
                    `util::prng` (thread_rng, rand::, fastrand, ...).
- ``float-reduce``— f32 reductions (`.sum::<f32>()`, `.fold(0.0f32`,
                    `.product::<f32>()`) whose result depends on
                    operand order; accumulate in f64 or use the blessed
                    `_into` kernels instead.

Escapes, in order of preference:

1. Fix the code.
2. Inline ``// lint:allow(<rule>)`` on the offending line, with a
   neighboring comment saying why it is sound.
3. The checked-in baseline (``scripts/lint_determinism_baseline.json``)
   — pre-existing findings only; regenerate with ``--update-baseline``
   and justify additions in review.

Lines inside a file's trailing ``#[cfg(test)]`` region are skipped:
tests may time things and build scratch maps freely.

``--mirrors`` runs a different check: constants that exist in both the
Rust source and its Python mirror tests (EWMA alpha, calibration trust
region, histogram bucket count) are extracted from both sides and must
agree — the mirror suite pins semantics only while the constants match.

Exit codes: 0 clean, 1 findings or mirror mismatch, 2 usage error.
"""

import argparse
import json
import re
import sys
from pathlib import Path

# files allowed to read wall-clock time: serving metrics account real
# latency there, benches measure it, and the CLI reports it
WALLCLOCK_WHITELIST = {
    "rust/src/bench.rs",
    "rust/src/main.rs",
    "rust/src/coordinator/mod.rs",
    "rust/src/coordinator/server.rs",
    "rust/src/runtime/params.rs",
}

# dispatch/planning modules where hash-iteration order would leak into
# routing, placement, or batch composition
HASH_SENSITIVE_PREFIXES = ("rust/src/moe/", "rust/src/coordinator/")

RULES = {
    "wallclock": re.compile(r"\b(Instant|SystemTime)\b"),
    "hash-iter": re.compile(r"\bHash(Map|Set)\b"),
    "extern-rng": re.compile(
        r"\b(thread_rng|fastrand|getrandom|StdRng|SmallRng|OsRng)\b|\brand\s*::"
    ),
    "float-reduce": re.compile(
        r"\.(sum|product)::<f32>\(\)|\.fold\(\s*0(\.0)?_?f32\b"
    ),
}

ALLOW_RE = re.compile(r"lint:allow\(([a-z-]+(?:\s*,\s*[a-z-]+)*)\)")
TEST_REGION_RE = re.compile(r"^\s*#\[cfg\((all\()?\s*(test|loom)\b")

# --mirrors manifest: (name, rust file, rust regex, python file, python
# regex). Each regex must capture the literal in group 1; the two
# literals must parse to the same float.
MIRRORS = [
    (
        "traffic-ewma-alpha",
        "rust/src/moe/traffic.rs",
        r"DEFAULT_TRAFFIC_ALPHA:\s*f64\s*=\s*([0-9.]+)",
        "python/tests/test_traffic_mirror.py",
        r"DEFAULT_ALPHA\s*=\s*([0-9.]+)",
    ),
    (
        "calibration-min-scale",
        "rust/src/moe/calibrate.rs",
        r"min_scale:\s*([0-9.]+)",
        "python/tests/test_calibrate_mirror.py",
        r"MIN_SCALE\s*=\s*([0-9.]+)",
    ),
    (
        "calibration-max-scale",
        "rust/src/moe/calibrate.rs",
        r"max_scale:\s*([0-9.]+)",
        "python/tests/test_calibrate_mirror.py",
        r"MAX_SCALE\s*=\s*([0-9.]+)",
    ),
    (
        "calibration-max-offset",
        "rust/src/moe/calibrate.rs",
        r"max_offset:\s*([0-9.]+)",
        "python/tests/test_calibrate_mirror.py",
        r"MAX_OFFSET\s*=\s*([0-9.]+)",
    ),
    (
        "wait-histogram-buckets",
        "rust/src/coordinator/metrics.rs",
        r"counts:\s*\[u64;\s*([0-9]+)\]",
        "python/tests/test_metrics_mirror.py",
        r"HISTOGRAM_BUCKETS\s*=\s*([0-9]+)",
    ),
]


def strip_comment(line):
    """Best-effort removal of a trailing // comment (no string parsing)."""
    idx = line.find("//")
    return line if idx < 0 else line[:idx]


def scan_file(path, rel):
    """Yield (rule, lineno, stripped_content) findings for one file."""
    try:
        text = path.read_text(encoding="utf-8")
    except (OSError, UnicodeDecodeError):
        return
    in_tests = False
    for lineno, line in enumerate(text.splitlines(), start=1):
        if TEST_REGION_RE.match(line):
            # repo convention keeps the tests mod at the bottom of the
            # file; everything after the attribute is test-only
            in_tests = True
        if in_tests:
            continue
        allow = ALLOW_RE.search(line)
        allowed = set()
        if allow:
            allowed = {r.strip() for r in allow.group(1).split(",")}
        code = strip_comment(line)
        if not code.strip():
            continue
        for rule, pattern in RULES.items():
            if rule in allowed or "all" in allowed:
                continue
            if rule == "wallclock" and rel in WALLCLOCK_WHITELIST:
                continue
            if rule == "hash-iter" and not rel.startswith(HASH_SENSITIVE_PREFIXES):
                continue
            if pattern.search(code):
                yield rule, lineno, code.strip()


def scan_tree(root):
    findings = []
    src = root / "rust" / "src"
    if not src.is_dir():
        sys.exit(f"lint_determinism: no rust/src under {root}")
    for path in sorted(src.rglob("*.rs")):
        rel = path.relative_to(root).as_posix()
        for rule, lineno, content in scan_file(path, rel):
            findings.append(
                {"rule": rule, "file": rel, "line": lineno, "content": content}
            )
    return findings


def load_baseline(path):
    if not path.is_file():
        return set()
    entries = json.loads(path.read_text(encoding="utf-8"))
    return {(e["rule"], e["file"], e["content"]) for e in entries}


def write_baseline(path, findings):
    entries = sorted(
        {(f["rule"], f["file"], f["content"]) for f in findings}
    )
    payload = [
        {"rule": rule, "file": file, "content": content}
        for rule, file, content in entries
    ]
    path.write_text(json.dumps(payload, indent=2) + "\n", encoding="utf-8")


def check_mirrors(root):
    """Compare Rust constants against their Python mirror pins."""
    failures = []
    for name, rust_file, rust_re, py_file, py_re in MIRRORS:
        values = {}
        for side, rel, regex in (
            ("rust", rust_file, rust_re),
            ("python", py_file, py_re),
        ):
            path = root / rel
            if not path.is_file():
                failures.append(f"{name}: missing {side} file {rel}")
                break
            matches = re.findall(regex, path.read_text(encoding="utf-8"))
            if not matches:
                failures.append(f"{name}: no match for /{regex}/ in {rel}")
                break
            first = matches[0] if isinstance(matches[0], str) else matches[0][0]
            if any(
                (m if isinstance(m, str) else m[0]) != first for m in matches
            ):
                failures.append(
                    f"{name}: {rel} defines conflicting values {matches}"
                )
                break
            values[side] = (rel, first)
        if len(values) < 2:
            continue
        (r_rel, r_val), (p_rel, p_val) = values["rust"], values["python"]
        if float(r_val) != float(p_val):
            failures.append(
                f"{name}: {r_rel} has {r_val} but {p_rel} pins {p_val}"
            )
    return failures


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repository root to scan (default: this script's repo)",
    )
    parser.add_argument(
        "--baseline",
        type=Path,
        default=None,
        help="baseline JSON (default: <root>/scripts/lint_determinism_baseline.json)",
    )
    parser.add_argument(
        "--update-baseline",
        action="store_true",
        help="rewrite the baseline from current findings and exit 0",
    )
    parser.add_argument(
        "--mirrors",
        action="store_true",
        help="check Rust constants against their Python mirror pins instead",
    )
    args = parser.parse_args(argv)
    root = args.root.resolve()
    baseline_path = args.baseline or root / "scripts" / "lint_determinism_baseline.json"

    if args.mirrors:
        failures = check_mirrors(root)
        for f in failures:
            print(f"MIRROR DRIFT {f}")
        if failures:
            print(f"lint_determinism --mirrors: {len(failures)} drifted constant(s)")
            return 1
        print(f"lint_determinism --mirrors: {len(MIRRORS)} constant(s) in sync")
        return 0

    findings = scan_tree(root)
    if args.update_baseline:
        write_baseline(baseline_path, findings)
        print(
            f"lint_determinism: baseline rewritten with "
            f"{len(findings)} finding(s) at {baseline_path}"
        )
        return 0

    baseline = load_baseline(baseline_path)
    fresh = [
        f
        for f in findings
        if (f["rule"], f["file"], f["content"]) not in baseline
    ]
    for f in fresh:
        print(f"{f['file']}:{f['line']}: [{f['rule']}] {f['content']}")
    if fresh:
        print(
            f"lint_determinism: {len(fresh)} new finding(s) "
            f"({len(findings) - len(fresh)} baselined). Fix, "
            "lint:allow with justification, or --update-baseline."
        )
        return 1
    print(
        f"lint_determinism: clean ({len(findings)} baselined finding(s), "
        f"{len(baseline)} baseline entr(ies))"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
