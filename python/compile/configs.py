"""Mini MoE model configurations.

Two configs mirror the paper's two evaluation models (DeepSeekMoE-16B and
OLMoE-7B) at a CPU-trainable scale; see DESIGN.md §2 for the substitution
argument. Architectural *family* features are preserved:

- ``olmoe_mini``: every layer is an MoE layer; no shared expert
  (OLMoE: 16 layers all-MoE, 64 experts).
- ``dsmoe_mini``: layer 0 uses a dense FFN, subsequent layers are MoE with
  one always-on shared expert (DeepSeekMoE: dense first FFN + shared
  expert per MoE block).

Both use gated-MLP experts and token-choice top-2 routing, as the paper's
models do.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 512
    seq_len: int = 32
    d_model: int = 48
    n_heads: int = 4
    n_layers: int = 4
    n_experts: int = 16
    top_k: int = 2
    d_expert: int = 64          # m, per-expert hidden width (gated MLP)
    # DeepSeek-style extras (0 / False disables):
    d_shared: int = 0           # shared-expert hidden width
    dense_first_layer: bool = False
    d_dense_ffn: int = 192      # dense FFN width used when a layer is dense
    # training
    lr: float = 0.05
    momentum: float = 0.9
    batch: int = 32
    train_steps: int = 600
    aux_loss_coef: float = 0.01
    init_scale: float = 0.08
    seed: int = 0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def is_moe_layer(self, layer: int) -> bool:
        return not (self.dense_first_layer and layer == 0)

    def to_dict(self):
        return asdict(self)


OLMOE_MINI = ModelConfig(name="olmoe_mini")

DSMOE_MINI = ModelConfig(
    name="dsmoe_mini",
    d_expert=56,
    d_shared=32,
    dense_first_layer=True,
    d_dense_ffn=192,
    seed=1,
)

CONFIGS = {c.name: c for c in (OLMOE_MINI, DSMOE_MINI)}


# ---------------------------------------------------------------------------
# AIMC / quantization defaults (paper §2.2, §5.1)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AimcConfig:
    """DAC-ADC quantization settings for the analog compute path.

    The paper uses 8-bit DAC and ADC (§5.2) and NVM tile size 512 (§5.1).
    ``kappa``/``lam`` are the global calibration hyper-parameters of
    eqs (4)-(5); the values here are the post-calibration defaults
    (Appendix B finds an interior optimum for both).
    """

    bits_dac: int = 8
    bits_adc: int = 8
    tile_size: int = 512
    kappa: float = 8.0
    lam: float = 1.0


DEFAULT_AIMC = AimcConfig()
