from . import aimc_mvm, ref  # noqa: F401
