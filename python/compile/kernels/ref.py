"""Pure-jnp correctness oracle for the AIMC compute path.

These functions define the *semantics* of analog matrix-vector
multiplication on an NVM crossbar tile (paper §2.2):

- eq (4): DAC quantization of the digital input to ``bits_dac`` levels in
  a fixed range ``beta_in``.
- eq (5): ADC quantization of the analog column currents to ``bits_adc``
  levels in a per-column range ``beta_out = lam * beta_in * max|W_:,i|``.
- tiling: a weight matrix larger than the crossbar is split into
  ``tile x tile`` sub-arrays; each row-tile is a separate analog MVM whose
  output passes through its own ADC, and partial sums are accumulated
  digitally.

The Pallas kernel in ``aimc_mvm.py`` must match these functions bit-for-
bit at f32 (pytest asserts allclose with tight tolerances), and the L2
model's in-graph fake-quant path reuses these functions directly, so the
serving path (Pallas) and the eval path (ref) are provably consistent.

The weight-programming noise model (eq (3), the Le Gallo 2023 PCM fit) is
also implemented here in numpy as the oracle for the Rust
``aimc::program`` implementation — programming noise is a *program-time*
effect applied to weights before they reach either compute path.
"""

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# eq (4): DAC input quantization
# ---------------------------------------------------------------------------

def dac_quant(x, beta_in, bits_dac):
    """Quantize activations to ``bits_dac``-bit signed levels in [-beta_in, beta_in].

    x_q = beta/(2^{b-1}-1) * round( clamp(x, -beta, beta) * (2^{b-1}-1)/beta )
    """
    levels = float(2 ** (bits_dac - 1) - 1)
    scale = levels / beta_in
    return jnp.round(jnp.clip(x, -beta_in, beta_in) * scale) / scale


# ---------------------------------------------------------------------------
# eq (5): ADC output quantization (per column)
# ---------------------------------------------------------------------------

def adc_quant(y, beta_out, bits_adc):
    """Quantize column currents to ``bits_adc``-bit levels, clamped to beta_out.

    ``beta_out`` broadcasts over the last (column) axis.
    """
    levels = float(2 ** (bits_adc - 1) - 1)
    scale = levels / beta_out
    return jnp.clip(jnp.round(y * scale) / scale, -beta_out, beta_out)


def beta_out_for(w_tile, beta_in, lam):
    """eq (5) output range: lam * beta_in * max|W_:,i| per column of a tile.

    Guarded away from zero so all-zero columns don't produce NaNs.
    """
    col_max = jnp.max(jnp.abs(w_tile), axis=0)
    return lam * beta_in * jnp.maximum(col_max, 1e-12)


# ---------------------------------------------------------------------------
# Analog tiled MVM (the oracle for the Pallas kernel)
# ---------------------------------------------------------------------------

def aimc_mvm_ref(x, w, beta_in, lam, bits_dac=8, bits_adc=8, tile=512):
    """Analog MVM y = x @ w through DAC -> crossbar tiles -> ADC.

    x: [t, d] activations, w: [d, n] weights (already programming-noised
    if the expert lives on the analog accelerator), beta_in: scalar input
    range (kappa * std of the tile input, calibrated), lam: ADC range
    hyper-parameter.

    The d axis is split into row tiles (wordlines), the n axis into column
    tiles (bitlines); every (row, col) tile is one crossbar array with its
    own DAC on the input slice and ADC on the output slice. Partial sums
    across row tiles accumulate digitally *after* the ADC, exactly as a
    multi-tile AIMC mapping does.
    """
    t, d = x.shape
    d2, n = w.shape
    assert d == d2
    y = jnp.zeros((t, n), dtype=x.dtype)
    for r0 in range(0, d, tile):
        r1 = min(r0 + tile, d)
        x_blk = dac_quant(x[:, r0:r1], beta_in, bits_dac)
        for c0 in range(0, n, tile):
            c1 = min(c0 + tile, n)
            w_blk = w[r0:r1, c0:c1]
            part = x_blk @ w_blk
            bo = beta_out_for(w_blk, beta_in, lam)
            part = adc_quant(part, bo, bits_adc)
            y = y.at[:, c0:c1].add(part)
    return y


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def gated_ffn_ref(x, w_up, w_gate, w_down, beta_in_up, beta_in_down, lam,
                  bits_dac=8, bits_adc=8, tile=512, analog=True):
    """Gated-MLP expert (eq (2) body, routing weight applied by caller).

    analog=True runs all three projections through the AIMC path; the
    SiLU + Hadamard product happens digitally between tiles (the paper's
    accelerators do nonlinearities in the digital periphery).
    """
    if analog:
        up = aimc_mvm_ref(x, w_up, beta_in_up, lam, bits_dac, bits_adc, tile)
        gate = aimc_mvm_ref(x, w_gate, beta_in_up, lam, bits_dac, bits_adc, tile)
        act = silu(up) * gate
        return aimc_mvm_ref(act, w_down, beta_in_down, lam, bits_dac, bits_adc, tile)
    act = silu(x @ w_up) * (x @ w_gate)
    return act @ w_down


# ---------------------------------------------------------------------------
# eq (3): weight-programming noise (numpy oracle; applied program-time)
# ---------------------------------------------------------------------------

# PCM coefficient fits from Le Gallo et al. 2023 (64-core PCM chip), as
# quoted in the paper §2.2: branch HI for |W| > 0.292 * Wmax, else LO.
PCM_SPLIT = 0.292
PCM_COEF_HI = (0.012, 0.245, -0.54, 0.40)
PCM_COEF_LO = (0.014, 0.224, -0.72, 0.952)


def programming_sigma(w, w_max):
    """Per-element noise std sigma_ij of eq (3).

    sigma = c0*Wmax + sum_{u=1..3} c_u |W|^u / Wmax^{u-1}, with the
    coefficient set chosen per element by the |W| / Wmax split.
    """
    w = np.asarray(w, dtype=np.float64)
    w_max = float(max(w_max, 1e-12))
    aw = np.abs(w)
    r = aw / w_max
    sig = np.empty_like(w)
    for coef, mask in ((PCM_COEF_HI, r > PCM_SPLIT), (PCM_COEF_LO, r <= PCM_SPLIT)):
        c0, c1, c2, c3 = coef
        s = c0 * w_max + c1 * aw + c2 * aw**2 / w_max + c3 * aw**3 / w_max**2
        sig[mask] = s[mask]
    # the fitted cubic can dip below zero for mid-range |W|; a std is >= 0
    return np.maximum(sig, 0.0)


def program_weights_ref(w, rng, noise_scale=1.0, tile=512):
    """Program a weight matrix onto NVM tiles: W_hat = W + N(0, (scale*sigma)^2).

    Wmax is *per column per tile* (the paper defines Wmax as the maximum
    weight magnitude of the column in the NVM tile). ``noise_scale``
    multiplies sigma and is the x-axis of Figs 3-5.
    """
    w = np.asarray(w, dtype=np.float64)
    out = w.copy()
    d, n = w.shape
    for r0 in range(0, d, tile):
        r1 = min(r0 + tile, d)
        for c in range(n):
            col = w[r0:r1, c]
            w_max = np.max(np.abs(col))
            if w_max <= 0:
                continue
            sig = programming_sigma(col, w_max) * noise_scale
            out[r0:r1, c] = col + rng.standard_normal(col.shape) * sig
    return out.astype(np.float32)
