"""L1 Pallas kernel: tiled analog in-memory MVM (DAC -> crossbar -> ADC).

Hardware adaptation (DESIGN.md §4): the paper's compute substrate is a PCM
crossbar, not a GPU, so the Pallas grid is laid out to mirror the *tile*
decomposition of an AIMC chip rather than a threadblock decomposition:

- grid = (col_tiles, row_tiles) over the weight matrix; each grid step is
  one crossbar array (``tile x tile``, paper uses 512).
- the input BlockSpec slice entering a tile is DAC-quantized (eq (4)) —
  on real hardware this is the HBM->VMEM boundary where the DAC sits.
- ``jnp.dot`` over the (rows, cols) block plays the crossbar MVM; on TPU
  this block shape feeds the MXU systolic array directly.
- the output block is ADC-quantized per column (eq (5)) and *accumulated
  digitally* across row tiles — matching the multi-tile partial-sum
  dataflow of the chip (ADC before accumulate, not after).

Numerical contract: identical results to ``ref.aimc_mvm_ref`` (pytest
enforces allclose at 1e-6). ``interpret=True`` always — the CPU PJRT
plugin cannot execute Mosaic custom-calls; TPU perf is estimated
analytically in DESIGN.md §7.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import beta_out_for, dac_quant

DEFAULT_TILE = 512


def _aimc_kernel(x_ref, w_ref, beta_ref, o_ref, *, bits_dac, bits_adc):
    """One (col_tile, row_tile) grid step = one crossbar array.

    x_ref:   [t, R]   input slice for this row tile (wordline segment)
    w_ref:   [R, C]   crossbar conductances (weight tile)
    beta_ref:[1, 2]   (beta_in, lam): DAC input range (calibrated
                      kappa * std) and the ADC range hyper-parameter.
                      Passed as a ref because both may be traced values
                      at lowering time (calibration varies them).
    o_ref:   [t, C]   output columns; accumulated across row tiles
    """
    row_tile = pl.program_id(1)
    beta_in = beta_ref[0, 0]
    lam = beta_ref[0, 1]

    # --- DAC: quantize the digital input entering the tile (eq 4) ---
    x_blk = dac_quant(x_ref[...], beta_in, bits_dac)

    # --- crossbar MVM: the analog dot product over this tile ---
    part = jnp.dot(x_blk, w_ref[...], preferred_element_type=jnp.float32)

    # --- ADC: per-column quantization of the tile's output currents (eq 5) ---
    bo = beta_out_for(w_ref[...], beta_in, lam)
    levels = float(2 ** (bits_adc - 1) - 1)
    scale = levels / bo
    part = jnp.clip(jnp.round(part * scale) / scale, -bo, bo)

    # --- digital accumulate across row tiles ---
    @pl.when(row_tile == 0)
    def _init():
        o_ref[...] = part

    @pl.when(row_tile != 0)
    def _accum():
        o_ref[...] += part


def aimc_mvm(x, w, beta_in, lam=1.0, bits_dac=8, bits_adc=8, tile=DEFAULT_TILE):
    """Analog MVM ``y = ADC(DAC(x) @ W)`` tiled over NVM crossbars.

    x: [t, d] f32, w: [d, n] f32 (programming-noised upstream if analog),
    beta_in: scalar f32 (traced — calibration varies it at runtime).
    Returns [t, n] f32.
    """
    t, d = x.shape
    d2, n = w.shape
    assert d == d2, f"shape mismatch {x.shape} @ {w.shape}"
    # Clamp the tile to the actual dims: at mini-model scale a whole
    # projection matrix fits a single 512x512 crossbar (DESIGN.md §2).
    tile_r = min(tile, d)
    tile_c = min(tile, n)
    # Pad to tile multiples: interpret-mode pallas fills out-of-bounds
    # block reads with NaN, so ragged edges must be zero-padded here.
    # Zero rows/cols are exact no-ops for the analog math (zero columns
    # hit the beta_out floor guard and quantize to zero).
    d_pad = pl.cdiv(d, tile_r) * tile_r
    n_pad = pl.cdiv(n, tile_c) * tile_c
    if d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, d_pad - d)))
        w = jnp.pad(w, ((0, d_pad - d), (0, 0)))
    if n_pad != n:
        w = jnp.pad(w, ((0, 0), (0, n_pad - n)))
    grid = (n_pad // tile_c, d_pad // tile_r)
    beta_arr = jnp.stack([
        jnp.asarray(beta_in, jnp.float32).reshape(()),
        jnp.asarray(lam, jnp.float32).reshape(()),
    ]).reshape(1, 2)

    kernel = functools.partial(
        _aimc_kernel, bits_dac=bits_dac, bits_adc=bits_adc
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # input rows follow the row tile; full token batch per step
            pl.BlockSpec((t, tile_r), lambda i, j: (0, j)),
            # weight tile (j-th row block, i-th col block) = one crossbar
            pl.BlockSpec((tile_r, tile_c), lambda i, j: (j, i)),
            # (beta_in, lam) scalars broadcast to every tile
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t, tile_c), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((t, n_pad), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, beta_arr)[:, :n]


def gated_ffn_analog(x, w_up, w_gate, w_down, beta_in_up, beta_in_down,
                     lam=1.0, bits_dac=8, bits_adc=8, tile=DEFAULT_TILE):
    """Gated-MLP expert on the analog accelerator (eq (2) body).

    Three crossbar-mapped projections; SiLU and the Hadamard product run
    in the digital periphery between tiles, as on the paper's chip.
    """
    up = aimc_mvm(x, w_up, beta_in_up, lam, bits_dac, bits_adc, tile)
    gate = aimc_mvm(x, w_gate, beta_in_up, lam, bits_dac, bits_adc, tile)
    act = up * (1.0 / (1.0 + jnp.exp(-up))) * gate
    return aimc_mvm(act, w_down, beta_in_down, lam, bits_dac, bits_adc, tile)
