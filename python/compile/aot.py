"""AOT build: train the mini models, lower every entry point to HLO text,
write weights + datasets. Runs ONCE at `make artifacts`; Python is never
on the request path.

Interchange format is HLO *text* (not serialized HloModuleProto): jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` 0.1.6 crate) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Artifacts layout (ABI documented in artifacts/meta.json):

  artifacts/
    meta.json                     — configs, flag layout, file formats
    data/corpus.bin  calib.bin    — i32 LE rows [n, seq_len]
    data/freq.json                — token frequencies (Fig 6 analysis)
    data/tasks/<task>.json        — multiple-choice items
    <cfg>/model_fwd.hlo.txt       — monolithic scoring forward
    <cfg>/train_step.hlo.txt      — SGD-momentum step (digital)
    <cfg>/attn_block.<l>.hlo.txt  — serving units (one per layer shape)
    <cfg>/expert_ffn_digital.hlo.txt / expert_ffn_analog.hlo.txt
    <cfg>/lm_head.hlo.txt
    <cfg>/params.bin manifest.json — trained weights, flat f32 LE
    <cfg>/init_params.bin          — untrained weights (for train_moe demo)
    <cfg>/train_log.json           — loss curve of the build-time training
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from .configs import CONFIGS, DEFAULT_AIMC


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def write_params(path, plist):
    flat = np.concatenate([np.asarray(p, np.float32).ravel() for p in plist])
    flat.astype("<f4").tofile(path)


def manifest_for(cfg):
    specs = M.param_specs(cfg)
    out, off = [], 0
    for name, shape in specs:
        n = int(np.prod(shape))
        out.append({"name": name, "shape": list(shape), "offset": off, "len": n})
        off += n
    return {"tensors": out, "total_f32": off}


# ---------------------------------------------------------------------------
# build-time training
# ---------------------------------------------------------------------------

def train(cfg, rows, log_every=100):
    plist = [jnp.asarray(p) for p in M.init_params(cfg)]
    mlist = [jnp.zeros_like(p) for p in plist]
    step_fn = jax.jit(
        lambda ps, ms, t, y, mk, lr: M.train_step(cfg, ps, ms, t, y, mk, lr)
    )
    rng = np.random.default_rng(cfg.seed + 77)
    n = rows.shape[0]
    log = []
    t0 = time.time()
    for step in range(cfg.train_steps):
        idx = rng.integers(0, n, cfg.batch)
        tokens, targets, mask = D.rows_to_batch(rows[idx])
        # cosine decay with short warmup
        warm = min(1.0, (step + 1) / 50)
        lr = cfg.lr * warm * 0.5 * (1 + np.cos(np.pi * step / cfg.train_steps))
        plist, mlist, nll = step_fn(plist, mlist, jnp.asarray(tokens),
                                    jnp.asarray(targets), jnp.asarray(mask),
                                    jnp.float32(lr))
        if step % log_every == 0 or step == cfg.train_steps - 1:
            v = float(nll)
            log.append({"step": step, "nll": v})
            print(f"  [{cfg.name}] step {step:5d} nll {v:.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    return [np.asarray(p) for p in plist], log


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def lower_all(cfg, out_dir, serve_cap):
    specs = M.param_specs(cfg)
    pspecs = [f32(s) for _, s in specs]
    B, T, d = cfg.batch, cfg.seq_len, cfg.d_model
    F = M.flags_len(cfg)
    scalar = f32(())

    entries = {}

    entries["model_fwd"] = jax.jit(
        lambda *a: M.model_fwd(cfg, list(a[:len(pspecs)]), *a[len(pspecs):])
    ).lower(*pspecs, i32((B, T)), i32((B, T)), f32((B, T)), f32((F,)),
            scalar, scalar)

    n_p = len(pspecs)
    entries["train_step"] = jax.jit(
        lambda *a: M.train_step(cfg, list(a[:n_p]), list(a[n_p:2 * n_p]),
                                *a[2 * n_p:])
    ).lower(*pspecs, *pspecs, i32((B, T)), i32((B, T)), f32((B, T)), scalar)

    entries["attn_block"] = jax.jit(
        lambda x, s, b, wq, wk, wv, wo, fl, ka, la: M.attn_block(
            cfg, x, s, b, wq, wk, wv, wo, fl, ka, la)
    ).lower(f32((B, T, d)), f32((d,)), f32((d,)), f32((d, d)), f32((d, d)),
            f32((d, d)), f32((d, d)), scalar, scalar, scalar)

    m = cfg.d_expert
    # Two capacity tiers per expert-FFN variant: the serving engine picks
    # the smallest tier that fits a dispatch chunk, cutting padded compute
    # ~8x for small batches (EXPERIMENTS.md §Perf iteration 2).
    small_cap = max(serve_cap // 8, 8)
    for cap, suffix in ((serve_cap, ""), (small_cap, f".c{small_cap}")):
        entries[f"expert_ffn_digital{suffix}"] = jax.jit(
            M.expert_ffn_digital
        ).lower(f32((cap, d)), f32((d, m)), f32((d, m)), f32((m, d)))

        entries[f"expert_ffn_analog{suffix}"] = jax.jit(
            lambda x, u, g, w, ka, la: M.expert_ffn_analog(x, u, g, w, ka, la)
        ).lower(f32((cap, d)), f32((d, m)), f32((d, m)), f32((m, d)),
                scalar, scalar)

    entries["lm_head"] = jax.jit(
        lambda h, s, b, w, t, fl, ka, la: M.lm_head_score(
            cfg, h, s, b, w, t, fl, ka, la)
    ).lower(f32((B * T, d)), f32((d,)), f32((d,)), f32((d, cfg.vocab)),
            i32((B * T,)), scalar, scalar, scalar)

    for name, lowered in entries.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {path} ({len(text)//1024} KiB)", flush=True)


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-rows", type=int, default=20000)
    ap.add_argument("--calib-rows", type=int, default=512)
    ap.add_argument("--task-items", type=int, default=128)
    ap.add_argument("--serve-cap", type=int, default=256,
                    help="max tokens per expert dispatch in the serving path")
    ap.add_argument("--steps", type=int, default=0,
                    help="override train steps (0 = config default)")
    ap.add_argument("--configs", default="olmoe_mini,dsmoe_mini")
    ap.add_argument("--lower-only", action="store_true",
                    help="re-lower HLO entry points; keep existing "
                         "params/data (used when only graph code changed)")
    args = ap.parse_args()

    out = args.out
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "data", "tasks"), exist_ok=True)

    if args.lower_only:
        for name in args.configs.split(","):
            cfg = CONFIGS[name]
            cdir = os.path.join(out, cfg.name)
            os.makedirs(cdir, exist_ok=True)
            print(f"[{cfg.name}] re-lowering HLO entry points...", flush=True)
            lower_all(cfg, cdir, args.serve_cap)
        print("lower-only complete", flush=True)
        return

    cfg0 = next(iter(CONFIGS.values()))
    lang, train_rows, calib_rows, tasks = D.generate_all(
        cfg0.vocab, cfg0.seq_len, args.train_rows, args.calib_rows,
        args.task_items)
    train_rows.astype("<i4").tofile(os.path.join(out, "data", "corpus.bin"))
    calib_rows.astype("<i4").tofile(os.path.join(out, "data", "calib.bin"))
    for t in tasks:
        with open(os.path.join(out, "data", "tasks", t["name"] + ".json"), "w") as f:
            json.dump(t, f)
    freq = D.token_frequencies(train_rows, cfg0.vocab)
    with open(os.path.join(out, "data", "freq.json"), "w") as f:
        json.dump({"freq": freq.tolist(),
                   "succ": lang.succ.tolist(), "word0": D.WORD0}, f)
    print(f"data: {train_rows.shape[0]} train rows, {len(tasks)} tasks", flush=True)

    meta = {"aimc": {"bits_dac": DEFAULT_AIMC.bits_dac,
                     "bits_adc": DEFAULT_AIMC.bits_adc,
                     "tile_size": DEFAULT_AIMC.tile_size,
                     "kappa": DEFAULT_AIMC.kappa, "lam": DEFAULT_AIMC.lam},
            "serve_cap": args.serve_cap,
            "data": {"seq_len": cfg0.seq_len, "vocab": cfg0.vocab,
                     "n_train_rows": int(train_rows.shape[0]),
                     "n_calib_rows": int(calib_rows.shape[0]),
                     "pad": D.PAD, "bos": D.BOS},
            "configs": {}}

    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        if args.steps:
            cfg = type(cfg)(**{**cfg.to_dict(), "train_steps": args.steps})
        cdir = os.path.join(out, cfg.name)
        os.makedirs(cdir, exist_ok=True)

        print(f"[{cfg.name}] lowering HLO entry points...", flush=True)
        lower_all(cfg, cdir, args.serve_cap)

        write_params(os.path.join(cdir, "init_params.bin"), M.init_params(cfg))
        print(f"[{cfg.name}] training {cfg.train_steps} steps...", flush=True)
        plist, log = train(cfg, train_rows)
        write_params(os.path.join(cdir, "params.bin"), plist)
        with open(os.path.join(cdir, "manifest.json"), "w") as f:
            json.dump(manifest_for(cfg), f)
        with open(os.path.join(cdir, "train_log.json"), "w") as f:
            json.dump(log, f)

        meta["configs"][cfg.name] = {
            **cfg.to_dict(),
            "flags_len": M.flags_len(cfg),
            "n_params": manifest_for(cfg)["total_f32"],
        }

    with open(os.path.join(out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print("artifacts complete", flush=True)


if __name__ == "__main__":
    main()
