"""L2: mini MoE transformer in JAX — forward, heterogeneous forward, train step.

This module defines everything that is AOT-lowered to HLO text and later
executed from the Rust coordinator via PJRT (see aot.py):

- ``model_fwd``      — monolithic scoring forward with per-module
  ``analog_flags`` controlling the in-graph DAC-ADC fake-quant path
  (eqs 4-5 via kernels.ref). Weight-programming noise (eq 3) is NOT in
  the graph: it is a program-time effect the Rust ``aimc`` module applies
  to the parameter buffers of analog-placed experts before execution.
- ``train_step``     — digital fwd/bwd + SGD-momentum update. The paper's
  method is retraining-free; training exists only to *create* the mini
  models at artifact-build time (DESIGN.md §2).
- per-sublayer entry points (``attn_block``, ``expert_ffn_digital``,
  ``expert_ffn_analog``, ``lm_head_score``) for the Rust serving engine,
  which owns embedding lookup, LayerNorm, routing and expert
  scatter/gather and dispatches these units to the two accelerators.
  ``expert_ffn_analog`` routes through the L1 Pallas kernel.

Parameters cross the boundary as a flat, canonically-ordered list (see
``param_specs``); aot.py writes the same order into manifest.json so the
Rust side can address tensors by name.
"""

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import aimc_mvm as pk
from .kernels.ref import adc_quant, dac_quant, silu

LN_EPS = 1e-5
BETA_EPS = 1e-6


# ---------------------------------------------------------------------------
# Parameter manifest (canonical flat ordering shared with Rust)
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig):
    """Ordered list of (name, shape). This order IS the ABI with Rust."""
    d, e, m = cfg.d_model, cfg.n_experts, cfg.d_expert
    specs = [
        ("embed", (cfg.vocab, d)),
        ("pos_emb", (cfg.seq_len, d)),
    ]
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        specs += [
            (p + "ln1.s", (d,)), (p + "ln1.b", (d,)),
            (p + "attn.wq", (d, d)), (p + "attn.wk", (d, d)),
            (p + "attn.wv", (d, d)), (p + "attn.wo", (d, d)),
            (p + "ln2.s", (d,)), (p + "ln2.b", (d,)),
        ]
        if cfg.is_moe_layer(l):
            specs += [
                (p + "router", (d, e)),
                (p + "experts.up", (e, d, m)),
                (p + "experts.gate", (e, d, m)),
                (p + "experts.down", (e, m, d)),
            ]
            if cfg.d_shared:
                ms = cfg.d_shared
                specs += [
                    (p + "shared.up", (d, ms)),
                    (p + "shared.gate", (d, ms)),
                    (p + "shared.down", (ms, d)),
                ]
        else:
            mf = cfg.d_dense_ffn
            specs += [
                (p + "ffn.up", (d, mf)),
                (p + "ffn.gate", (d, mf)),
                (p + "ffn.down", (mf, d)),
            ]
    specs += [("ln_f.s", (d,)), ("ln_f.b", (d,)), ("lm_head", (cfg.d_model, cfg.vocab))]
    return specs


def init_params(cfg: ModelConfig, seed=None):
    """Deterministic init matching param_specs order. Returns list of np f32."""
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    out = []
    for name, shape in param_specs(cfg):
        if name.endswith(".s"):
            arr = np.ones(shape, np.float32)
        elif name.endswith(".b"):
            arr = np.zeros(shape, np.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            std = cfg.init_scale if len(shape) < 2 else min(cfg.init_scale, 1.0 / math.sqrt(fan_in))
            arr = (rng.standard_normal(shape) * std).astype(np.float32)
        out.append(arr)
    return out


class ParamView:
    """Name-addressed view over the flat param list."""

    def __init__(self, cfg, plist):
        self.idx = {name: i for i, (name, _) in enumerate(param_specs(cfg))}
        self.plist = plist

    def __getitem__(self, name):
        return self.plist[self.idx[name]]


# ---------------------------------------------------------------------------
# analog_flags layout (ABI with Rust; see aot.py meta.json)
# ---------------------------------------------------------------------------
# [ L*E expert flags (row-major layer, expert) ]
# [ L   attn flags   ]  (wq/wk/wv/wo of layer l)
# [ L   dense-ffn / shared-expert flags ]
# [ 1   lm_head flag ]

def flags_len(cfg):
    return cfg.n_layers * cfg.n_experts + 2 * cfg.n_layers + 1


def split_flags(cfg, flags):
    le = cfg.n_layers * cfg.n_experts
    expert = flags[:le].reshape(cfg.n_layers, cfg.n_experts)
    attn = flags[le:le + cfg.n_layers]
    dense = flags[le + cfg.n_layers:le + 2 * cfg.n_layers]
    lm = flags[le + 2 * cfg.n_layers]
    return expert, attn, dense, lm


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def layer_norm(x, s, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + LN_EPS) * s + b


def batch_beta_in(x, kappa):
    """Calibrated DAC input range: beta_in = kappa * std(x).

    The paper calibrates beta_in = kappa * EMA-std over a calibration set;
    we use the batch std of the tile input, which tracks the same scale at
    our batch sizes (DESIGN.md §2) and keeps kappa/lam the only calibrated
    hyper-parameters — exactly the knobs Appendix B sweeps.
    """
    return kappa * jnp.std(x) + BETA_EPS


def maybe_analog_linear(x, w, flag, kappa, lam, bits_dac, bits_adc):
    """y = x @ w, with the DAC-ADC path blended in where flag > 0.

    Single matmul: the input is DAC-quantized pre-matmul and the output
    ADC-quantized post-matmul only when the module is flagged analog, so
    the digital path pays no extra FLOPs.
    """
    beta_in = batch_beta_in(x, kappa)
    xin = jnp.where(flag > 0, dac_quant(x, beta_in, bits_dac), x)
    y = xin @ w
    bo = lam * beta_in * jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-12)
    return jnp.where(flag > 0, adc_quant(y, bo, bits_adc), y)


def attention(cfg, x, wq, wk, wv, wo, flag, kappa, lam, bits_dac, bits_adc):
    """Causal MHSA over x [B, T, d]; the four projections share one flag."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    x2 = x.reshape(b * t, d)
    lin = partial(maybe_analog_linear, kappa=kappa, lam=lam,
                  bits_dac=bits_dac, bits_adc=bits_adc)
    q = lin(x2, wq, flag).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = lin(x2, wk, flag).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = lin(x2, wv, flag).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    causal = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(causal[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b * t, d)
    return lin(o, wo, flag).reshape(b, t, d)


def router_gates(cfg, u, router_w):
    """Token-choice top-k routing (§2.1). Returns dense gate matrix [N, E].

    Gates are the softmax over the top-k routing scores (renormalized),
    scattered back to a dense [N, E] matrix so expert compute can run as a
    dense einsum over stacked expert weights at mini-model scale.

    top-k is computed by iterative masked argmax rather than
    ``jax.lax.top_k``: jax >= 0.7 lowers top_k to an HLO ``topk(...)
    largest=true`` instruction whose text form the xla_extension 0.5.1
    parser (behind the rust `xla` crate) rejects. Iterative max lowers to
    plain reduce/select ops that round-trip cleanly, and for k=2 costs
    two O(E) passes — cheaper than a sort at E=16 anyway.
    """
    scores = u @ router_w                             # [N, E]
    probs = jax.nn.softmax(scores, axis=-1)
    masked = scores
    sel_masks, sel_vals = [], []
    for _ in range(cfg.top_k):
        mx = jnp.max(masked, axis=-1, keepdims=True)   # [N, 1]
        hit = masked >= mx
        # break ties toward the lowest index (matches lax.top_k)
        first = jnp.cumsum(hit.astype(jnp.float32), axis=-1) <= 1.0
        hit = hit & first
        sel_masks.append(hit.astype(scores.dtype))
        sel_vals.append(mx)
        masked = jnp.where(hit, -1e30, masked)
    vals = jnp.concatenate(sel_vals, axis=-1)          # [N, k]
    gates = jax.nn.softmax(vals, axis=-1)              # [N, k]
    gmat = sum(gates[:, i:i + 1] * sel_masks[i] for i in range(cfg.top_k))
    return gmat, probs


def moe_experts(u, w_up, w_gate, w_down, gmat, eflags, kappa, lam,
                bits_dac, bits_adc):
    """All-experts dense compute with per-expert analog fake-quant blend.

    u [N, d]; stacked weights [E, d, m] / [E, m, d]; gmat [N, E] dense
    gates (zero for unrouted experts); eflags [E].

    Per-expert analog selection happens on the *input* side (select the
    DAC-quantized input for flagged experts, exact input otherwise) so
    every projection costs exactly one batched einsum — no duplicated
    FLOPs for the blended graph (important on this 1-core testbed; see
    EXPERIMENTS.md §Perf).
    """
    ef = eflags[None, :, None]                         # [1, E, 1]
    beta_u = batch_beta_in(u, kappa)
    uq = dac_quant(u, beta_u, bits_dac)
    # [N, E, d] per-expert input view: quantized where the expert is analog
    xin = jnp.where(ef > 0, uq[:, None, :], u[:, None, :])

    def proj_in(w):                                    # w [E, d, m]
        y = jnp.einsum("ned,edm->nem", xin, w)
        bo = lam * beta_u * jnp.maximum(jnp.max(jnp.abs(w), axis=1), 1e-12)  # [E, m]
        return jnp.where(ef > 0, adc_quant(y, bo[None], bits_adc), y)

    up = proj_in(w_up)
    gate = proj_in(w_gate)
    act = silu(up) * gate                              # [N, E, m]

    beta_a = kappa * jnp.std(act, axis=(0, 2)) + BETA_EPS   # [E]
    act_q = dac_quant(act, beta_a[None, :, None], bits_dac)
    act_in = jnp.where(ef > 0, act_q, act)
    y_e = jnp.einsum("nem,emd->ned", act_in, w_down)
    bo_d = lam * beta_a[:, None] * jnp.maximum(jnp.max(jnp.abs(w_down), axis=1), 1e-12)  # [E, d]
    y_e = jnp.where(ef > 0, adc_quant(y_e, bo_d[None], bits_adc), y_e)
    return jnp.einsum("ne,ned->nd", gmat, y_e)


def gated_mlp(x, w_up, w_gate, w_down, flag, kappa, lam, bits_dac, bits_adc):
    """Dense gated FFN / shared expert with a single analog flag."""
    lin = partial(maybe_analog_linear, kappa=kappa, lam=lam,
                  bits_dac=bits_dac, bits_adc=bits_adc)
    act = silu(lin(x, w_up, flag)) * lin(x, w_gate, flag)
    return lin(act, w_down, flag)


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def backbone(cfg, pv, tokens, flags, kappa, lam, bits_dac=8, bits_adc=8,
             collect_router=False):
    """Shared trunk: tokens [B, T] -> hidden [B, T, d] (+ router stats)."""
    eflags, aflags, dflags, _ = split_flags(cfg, flags)
    b, t = tokens.shape
    d = cfg.d_model
    x = pv["embed"][tokens] + pv["pos_emb"][None, :t]
    router_stats = []
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        a = layer_norm(x, pv[p + "ln1.s"], pv[p + "ln1.b"])
        x = x + attention(cfg, a, pv[p + "attn.wq"], pv[p + "attn.wk"],
                          pv[p + "attn.wv"], pv[p + "attn.wo"],
                          aflags[l], kappa, lam, bits_dac, bits_adc)
        u3 = layer_norm(x, pv[p + "ln2.s"], pv[p + "ln2.b"])
        u = u3.reshape(b * t, d)
        if cfg.is_moe_layer(l):
            gmat, probs = router_gates(cfg, u, pv[p + "router"])
            y = moe_experts(u, pv[p + "experts.up"], pv[p + "experts.gate"],
                            pv[p + "experts.down"], gmat, eflags[l],
                            kappa, lam, bits_dac, bits_adc)
            if cfg.d_shared:
                y = y + gated_mlp(u, pv[p + "shared.up"], pv[p + "shared.gate"],
                                  pv[p + "shared.down"], dflags[l],
                                  kappa, lam, bits_dac, bits_adc)
            if collect_router:
                router_stats.append((gmat, probs))
        else:
            y = gated_mlp(u, pv[p + "ffn.up"], pv[p + "ffn.gate"],
                          pv[p + "ffn.down"], dflags[l],
                          kappa, lam, bits_dac, bits_adc)
        x = x + y.reshape(b, t, d)
    return x, router_stats


def token_logprobs(cfg, pv, x, targets, lm_flag, kappa, lam,
                   bits_dac=8, bits_adc=8):
    """log p(target_t | ...) per position. x [B, T, d] -> [B, T]."""
    b, t, d = x.shape
    h = layer_norm(x, pv["ln_f.s"], pv["ln_f.b"]).reshape(b * t, d)
    logits = maybe_analog_linear(h, pv["lm_head"], lm_flag, kappa, lam,
                                 bits_dac, bits_adc)
    logp = jax.nn.log_softmax(logits, axis=-1).reshape(b, t, cfg.vocab)
    return jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


def model_fwd(cfg, plist, tokens, targets, mask, flags, kappa, lam,
              bits_dac=8, bits_adc=8):
    """Scoring forward: per-sequence sum of masked target log-probs [B].

    This is the eval hot path: choice scoring (argmax over per-choice
    scores) and perplexity (exp(-sum(scores)/sum(mask))) both derive from
    the returned vector, keeping the PJRT transfer tiny.
    """
    pv = ParamView(cfg, plist)
    x, _ = backbone(cfg, pv, tokens, flags, kappa, lam, bits_dac, bits_adc)
    lm_flag = split_flags(cfg, flags)[3]
    logp = token_logprobs(cfg, pv, x, targets, lm_flag, kappa, lam,
                          bits_dac, bits_adc)
    return jnp.sum(logp * mask, axis=-1)


# ---------------------------------------------------------------------------
# training (digital only — the paper's deployment is retraining-free)
# ---------------------------------------------------------------------------

def train_loss(cfg, plist, tokens, targets, mask):
    pv = ParamView(cfg, plist)
    zero_flags = jnp.zeros((flags_len(cfg),), jnp.float32)
    x, stats = backbone(cfg, pv, tokens, zero_flags, 1.0, 1.0,
                        collect_router=True)
    logp = token_logprobs(cfg, pv, x, targets, 0.0, 1.0, 1.0)
    nll = -jnp.sum(logp * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    # Switch-transformer load-balance auxiliary: E * sum_e f_e * P_e
    aux = 0.0
    for gmat, probs in stats:
        f_e = jnp.mean((gmat > 0).astype(jnp.float32), axis=0)
        p_e = jnp.mean(probs, axis=0)
        aux = aux + cfg.n_experts * jnp.sum(f_e * p_e)
    n_moe = max(sum(cfg.is_moe_layer(l) for l in range(cfg.n_layers)), 1)
    return nll + cfg.aux_loss_coef * aux / n_moe, nll


def train_step(cfg, plist, mlist, tokens, targets, mask, lr):
    """One SGD-momentum step. Returns (plist', mlist', nll)."""
    (loss, nll), grads = jax.value_and_grad(
        lambda ps: train_loss(cfg, ps, tokens, targets, mask), has_aux=True
    )(list(plist))
    new_p, new_m = [], []
    for p, m, g in zip(plist, mlist, grads):
        m2 = cfg.momentum * m + g
        new_p.append(p - lr * m2)
        new_m.append(m2)
    return new_p, new_m, nll


# ---------------------------------------------------------------------------
# per-sublayer entry points for the Rust serving engine
# ---------------------------------------------------------------------------

def attn_block(cfg, x, ln1_s, ln1_b, wq, wk, wv, wo, flag, kappa, lam):
    """y = x + MHSA(LN(x)); the attention sublayer as one dispatchable unit."""
    a = layer_norm(x, ln1_s, ln1_b)
    return x + attention(cfg, a, wq, wk, wv, wo, flag, kappa, lam, 8, 8)


def expert_ffn_digital(x, w_up, w_gate, w_down):
    """Exact gated-MLP expert for the digital accelerator. x [cap, d]."""
    act = silu(x @ w_up) * (x @ w_gate)
    return act @ w_down


def expert_ffn_analog(x, w_up, w_gate, w_down, kappa, lam,
                      bits_dac=8, bits_adc=8, tile=512):
    """Analog gated-MLP expert via the L1 Pallas crossbar kernel.

    beta_in for the up/gate tiles comes from the live input batch std; the
    down tile's beta_in from the intermediate activation std — the same
    rule the monolithic graph uses, so serving == eval numerics.
    """
    beta_up = batch_beta_in(x, kappa)
    up = pk.aimc_mvm(x, w_up, beta_up, lam, bits_dac, bits_adc, tile)
    gate = pk.aimc_mvm(x, w_gate, beta_up, lam, bits_dac, bits_adc, tile)
    act = silu(up) * gate
    beta_dn = batch_beta_in(act, kappa)
    return pk.aimc_mvm(act, w_down, beta_dn, lam, bits_dac, bits_adc, tile)


def lm_head_score(cfg, h, ln_s, ln_b, w, targets, flag, kappa, lam):
    """Final-norm + LM head + target log-prob, as one unit. h [N, d]."""
    hh = layer_norm(h, ln_s, ln_b)
    logits = maybe_analog_linear(hh, w, flag, kappa, lam, 8, 8)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
