"""Synthetic structured corpus + the 8 benchmark-task analogs.

The paper evaluates on PIQA/ARC-e/ARC-c/BoolQ/HellaSwag/WinoGrande/MathQA/
MMLU through log-prob choice scoring. We build 8 synthetic multiple-choice
tasks with the *same protocol* over a synthetic language the mini models
are trained on (DESIGN.md §2). The language mixes five template families;
each task is a held-out probe of one family:

  chain       — a fixed random successor table over Zipf-distributed word
                tokens ("bigram grammar"); start-word frequency follows
                the Zipf law, so experts specializing in frequent chain
                tokens emerge — the mechanism behind MaxNNScore.
  arithmetic  — mod-10 "a op b = c" facts, op in {+, x}.
  containment — "ctx SEP Q x SEP -> YES/NO" (is x in ctx?).
  recall      — "w1 w2 w3 SEP Q d_k -> w_k" positional recall.
  filler      — raw Zipf unigram stream (frequency signal).

Tasks (chance level in parens):
  syn-piqa  (50%) chain continuation, 2 choices x 3 tokens
  syn-arce  (25%) chain cloze, frequent start words, 4 single-token choices
  syn-arcc  (25%) chain cloze, rare start words (the "challenge" split)
  syn-boolq (50%) containment YES/NO
  syn-hella (25%) chain continuation, 4 choices x 4 tokens
  syn-wino  (50%) positional recall, 2 choices
  syn-mathqa(25%) arithmetic result, 4 digit choices
  syn-mmlu  (25%) mixed cloze over all families

All randomness is seeded; `make artifacts` is reproducible bit-for-bit.
"""

import json
import numpy as np

# ---- token ids (ABI with Rust; written to data/meta.json) ----
PAD, BOS, SEP, Q, YES, NO = 0, 1, 2, 3, 4, 5
DIGIT0 = 6                      # digits d0..d9 = 6..15
OP_PLUS, OP_TIMES, EQ = 16, 17, 18
WORD0 = 20                      # word tokens 20..vocab-1

ZIPF_EXP = 1.1
ZIPF_SHIFT = 2.7


class Language:
    """The deterministic synthetic language: Zipf words + successor table."""

    def __init__(self, vocab=512, seed=1234):
        self.vocab = vocab
        self.n_words = vocab - WORD0
        rng = np.random.default_rng(seed)
        ranks = np.arange(self.n_words)
        w = 1.0 / (ranks + ZIPF_SHIFT) ** ZIPF_EXP
        self.zipf_p = w / w.sum()
        # successor table: random permutation => every word has a unique
        # successor, making chains unambiguous and learnable
        self.succ = rng.permutation(self.n_words)

    def word(self, i):
        return WORD0 + int(i)

    def sample_word(self, rng, lo=0, hi=None):
        """Zipf-sample a word index restricted to rank range [lo, hi)."""
        hi = self.n_words if hi is None else hi
        p = self.zipf_p[lo:hi]
        return lo + rng.choice(hi - lo, p=p / p.sum())

    def chain(self, start, length):
        out, cur = [], start
        for _ in range(length):
            out.append(self.word(cur))
            cur = int(self.succ[cur])
        return out


# ---------------------------------------------------------------------------
# sentence templates
# ---------------------------------------------------------------------------

def sent_chain(lang, rng, max_len):
    start = lang.sample_word(rng)
    n = int(rng.integers(8, max_len - 1))
    return [BOS] + lang.chain(start, n)


def sent_arith(lang, rng, max_len):
    toks = [BOS]
    for _ in range(int(rng.integers(2, 4))):
        a, b = int(rng.integers(10)), int(rng.integers(10))
        if rng.random() < 0.5:
            op, c = OP_PLUS, (a + b) % 10
        else:
            op, c = OP_TIMES, (a * b) % 10
        toks += [Q, DIGIT0 + a, op, DIGIT0 + b, EQ, DIGIT0 + c]
        if len(toks) + 6 > max_len:
            break
    return toks


def sent_contain(lang, rng, max_len):
    n_ctx = int(rng.integers(6, 11))
    ctx = [lang.word(lang.sample_word(rng)) for _ in range(n_ctx)]
    if rng.random() < 0.5:
        x = ctx[int(rng.integers(n_ctx))]
        ans = YES
    else:
        while True:
            xi = lang.sample_word(rng)
            if lang.word(xi) not in ctx:
                break
        x, ans = lang.word(xi), NO
    return [BOS] + ctx + [SEP, Q, x, SEP, ans]


def sent_recall(lang, rng, max_len):
    ws = []
    while len(ws) < 3:
        w = lang.word(lang.sample_word(rng))
        if w not in ws:
            ws.append(w)
    k = int(rng.integers(3))
    return [BOS] + ws + [SEP, Q, DIGIT0 + k + 1, ws[k]]


def sent_filler(lang, rng, max_len):
    n = int(rng.integers(8, max_len - 1))
    return [BOS] + [lang.word(lang.sample_word(rng)) for _ in range(n)]


TEMPLATES = [
    (sent_chain, 0.40),
    (sent_arith, 0.15),
    (sent_contain, 0.15),
    (sent_recall, 0.15),
    (sent_filler, 0.15),
]


def make_rows(lang, rng, n_rows, seq_len):
    """Sample sentences, one per row, PAD-padded to seq_len. i32 [n, T]."""
    fns = [t[0] for t in TEMPLATES]
    ps = np.array([t[1] for t in TEMPLATES])
    rows = np.zeros((n_rows, seq_len), np.int32)
    for i in range(n_rows):
        fn = fns[rng.choice(len(fns), p=ps)]
        s = fn(lang, rng, seq_len)[:seq_len]
        rows[i, :len(s)] = s
    return rows


def rows_to_batch(rows):
    """(tokens, targets, mask): next-token prediction within the sentence."""
    tokens = rows
    targets = np.zeros_like(rows)
    targets[:, :-1] = rows[:, 1:]
    mask = ((tokens != PAD) & (targets != PAD)).astype(np.float32)
    mask[:, -1] = 0.0
    return tokens, targets, mask


# ---------------------------------------------------------------------------
# eval tasks
# ---------------------------------------------------------------------------

def _distinct_words(lang, rng, n, lo=0, hi=None, exclude=()):
    out = []
    while len(out) < n:
        w = lang.word(lang.sample_word(rng, lo, hi))
        if w not in out and w not in exclude:
            out.append(w)
    return out


def task_piqa(lang, rng):
    start = lang.sample_word(rng)
    full = lang.chain(start, 9)
    ctx, gold = [BOS] + full[:6], full[6:9]
    wrong = gold
    while wrong == gold:
        wrong = lang.chain(lang.sample_word(rng), 3)
    return ctx, [gold, wrong]


def _cloze(lang, rng, lo, hi):
    start = lang.sample_word(rng, lo, hi)
    full = lang.chain(start, 6)
    ctx = [BOS] + full[:5]
    gold = [full[5]]
    distract = [[w] for w in _distinct_words(lang, rng, 3, exclude=(gold[0],))]
    return ctx, [gold] + distract


def task_arce(lang, rng):
    return _cloze(lang, rng, 0, max(8, lang.n_words // 16))


def task_arcc(lang, rng):
    return _cloze(lang, rng, lang.n_words // 4, lang.n_words)


def task_boolq(lang, rng):
    s = sent_contain(lang, rng, 32)
    ctx, ans = s[:-1], s[-1]
    return ctx, [[ans], [NO if ans == YES else YES]]


def task_hella(lang, rng):
    start = lang.sample_word(rng)
    full = lang.chain(start, 10)
    ctx, gold = [BOS] + full[:6], full[6:10]
    choices = [gold]
    while len(choices) < 4:
        o = lang.sample_word(rng)
        c = lang.chain(o, 4)
        if c != gold:
            choices.append(c)
    return ctx, choices


def task_wino(lang, rng):
    s = sent_recall(lang, rng, 32)
    ctx, ans = s[:-1], s[-1]
    ws = s[1:4]
    wrong = ws[(ws.index(ans) + 1) % 3]
    return ctx, [[ans], [wrong]]


def task_mathqa(lang, rng):
    a, b = int(rng.integers(10)), int(rng.integers(10))
    if rng.random() < 0.5:
        op, c = OP_PLUS, (a + b) % 10
    else:
        op, c = OP_TIMES, (a * b) % 10
    ctx = [BOS, Q, DIGIT0 + a, op, DIGIT0 + b, EQ]
    wrong = rng.permutation([d for d in range(10) if d != c])[:3]
    return ctx, [[DIGIT0 + c]] + [[DIGIT0 + int(w)] for w in wrong]


def task_mmlu(lang, rng):
    r = rng.random()
    if r < 0.34:
        return _cloze(lang, rng, 0, lang.n_words)
    if r < 0.67:
        return task_mathqa(lang, rng)
    ctx, choices = task_wino(lang, rng)
    while len(choices) < 4:
        w = lang.word(lang.sample_word(rng))
        if [w] not in choices:
            choices.append([w])
    return ctx, choices


TASKS = [
    ("syn-piqa", task_piqa),
    ("syn-arce", task_arce),
    ("syn-arcc", task_arcc),
    ("syn-boolq", task_boolq),
    ("syn-hella", task_hella),
    ("syn-wino", task_wino),
    ("syn-mathqa", task_mathqa),
    ("syn-mmlu", task_mmlu),
]


def make_task(lang, rng, name, fn, n_items, seq_len):
    items = []
    for _ in range(n_items):
        ctx, choices = fn(lang, rng)
        gold = 0
        # shuffle choices, track gold
        order = rng.permutation(len(choices))
        choices = [choices[int(i)] for i in order]
        gold = int(np.argwhere(order == 0)[0][0])
        longest = max(len(c) for c in choices)
        if len(ctx) + longest > seq_len:
            ctx = ctx[-(seq_len - longest):]
        items.append({"ctx": [int(t) for t in ctx],
                      "choices": [[int(t) for t in c] for c in choices],
                      "gold": gold})
    return {"name": name, "n_choices": len(items[0]["choices"]), "items": items}


def generate_all(vocab, seq_len, n_train_rows, n_calib_rows, n_items,
                 seed=1234):
    lang = Language(vocab=vocab, seed=seed)
    rng_train = np.random.default_rng(seed + 1)
    rng_calib = np.random.default_rng(seed + 2)
    rng_task = np.random.default_rng(seed + 3)
    train = make_rows(lang, rng_train, n_train_rows, seq_len)
    calib = make_rows(lang, rng_calib, n_calib_rows, seq_len)
    tasks = [make_task(lang, rng_task, name, fn, n_items, seq_len)
             for name, fn in TASKS]
    return lang, train, calib, tasks


def token_frequencies(rows, vocab):
    return np.bincount(rows.flatten(), minlength=vocab)
