"""Mirror of the WaitHistogram bucketing in rust/src/coordinator/metrics.rs.

The Rust histogram buckets queueing waits into ``HISTOGRAM_BUCKETS``
log2 buckets: bucket ``b`` covers waits ``[2^b - 1, 2^(b+1) - 2]``
(bucket 0 is exactly wait 0), and quantile estimates interpolate
linearly inside a bucket. This mirror re-derives both from the paper's
serving-metrics description and pins the arithmetic with integer-exact
cases, so a silent change to the Rust constants breaks a test here.

``HISTOGRAM_BUCKETS`` is additionally cross-checked against the Rust
source by ``scripts/lint_determinism.py --mirrors`` (the constant must
be defined once on each side, and agree).
"""

HISTOGRAM_BUCKETS = 32


def bucket(wait):
    """Mirror of WaitHistogram::bucket: floor(log2(wait + 1)), capped."""
    assert wait >= 0
    return min((wait + 1).bit_length() - 1, HISTOGRAM_BUCKETS - 1)


class HistogramMirror:
    """Pure-python WaitHistogram: record + merge + quantile."""

    def __init__(self):
        self.counts = [0] * HISTOGRAM_BUCKETS
        self.total = 0
        self.sum = 0
        self.max = 0

    def record(self, wait):
        self.counts[bucket(wait)] += 1
        self.total += 1
        self.sum += wait
        self.max = max(self.max, wait)

    def merge(self, other):
        for b in range(HISTOGRAM_BUCKETS):
            self.counts[b] += other.counts[b]
        self.total += other.total
        self.sum += other.sum
        self.max = max(self.max, other.max)

    def quantile(self, q):
        if self.total == 0:
            return 0.0
        rank = min(max(q, 0.0), 1.0) * (self.total - 1)
        cum = 0
        for b, c in enumerate(self.counts):
            if c == 0:
                continue
            if rank < cum + c:
                lo = (1 << b) - 1
                hi = max(min((1 << (b + 1)) - 2, self.max), lo)
                frac = min((rank - cum) / (c - 1), 1.0) if c > 1 else 1.0
                return lo + (hi - lo) * frac
            cum += c
        return float(self.max)


def test_bucket_edges_match_rust_doc():
    # bucket b covers [2^b - 1, 2^(b+1) - 2]; spot-check the first few
    # and the generic edge identity for every bucket
    assert bucket(0) == 0
    assert bucket(1) == 1
    assert bucket(2) == 1
    assert bucket(3) == 2
    assert bucket(6) == 2
    assert bucket(7) == 3
    for b in range(HISTOGRAM_BUCKETS - 1):
        lo = (1 << b) - 1
        hi = (1 << (b + 1)) - 2
        assert bucket(lo) == b
        assert bucket(hi) == b
    # the top bucket is saturating
    assert bucket((1 << 40) + 5) == HISTOGRAM_BUCKETS - 1


def test_quantile_interpolation_is_exact_on_uniform_bucket():
    # four waits in bucket 2 (3..=6): ranks 0..3 span lo=3 to hi=6
    h = HistogramMirror()
    for w in (3, 4, 5, 6):
        h.record(w)
    assert h.quantile(0.0) == 3.0
    assert h.quantile(1.0) == 6.0
    assert h.quantile(0.5) == 4.5


def test_quantile_monotone_across_bucket_gaps():
    # the regression shape from the Rust suite: {3, 3, 7, 7} must not
    # extrapolate past the bucket edge and break monotonicity
    h = HistogramMirror()
    for w in (3, 3, 7, 7):
        h.record(w)
    qs = [h.quantile(q / 20.0) for q in range(21)]
    assert all(a <= b for a, b in zip(qs, qs[1:]))
    assert h.quantile(0.95) <= h.max


def test_merge_equals_union_stream():
    # merging two histograms must quantile-match one histogram fed the
    # union of both wait streams
    a, b, u = HistogramMirror(), HistogramMirror(), HistogramMirror()
    left = [0, 1, 1, 4, 9]
    right = [2, 2, 30, 100]
    for w in left:
        a.record(w)
        u.record(w)
    for w in right:
        b.record(w)
        u.record(w)
    a.merge(b)
    assert a.counts == u.counts
    assert a.total == u.total and a.sum == u.sum and a.max == u.max
    for q in (0.0, 0.25, 0.5, 0.9, 1.0):
        assert a.quantile(q) == u.quantile(q)
