"""Routing-traffic EWMA mirror tests (issue 8 satellite).

Pure-python port of ``rust/src/moe/traffic.rs``'s ``TrafficStats``
semantics — EWMA update, per-layer sum-to-one invariant, pooled
frequency, and the update-count-weighted replica merge — fuzzed against
a reference implementation and pinned to the exact binary constants the
Rust unit test ``ewma_matches_python_mirror_constants`` asserts. No
numpy needed beyond convenience; no artifacts.
"""

import random

DEFAULT_ALPHA = 0.2


class TrafficMirror:
    """Line-for-line mirror of TrafficStats (the EWMA parts)."""

    def __init__(self, n_layers, n_experts, alpha=DEFAULT_ALPHA):
        assert 0.0 < alpha <= 1.0
        self.alpha = alpha
        self.shares = [[0.0] * n_experts for _ in range(n_layers)]
        self.updates = [0] * n_layers

    def update(self, layer, counts):
        total = sum(counts)
        if total == 0:
            return
        first = self.updates[layer] == 0
        row = self.shares[layer]
        for e, c in enumerate(counts):
            share = c / total
            row[e] = share if first else (1.0 - self.alpha) * row[e] + self.alpha * share
        self.updates[layer] += 1

    def frequency(self):
        n_experts = len(self.shares[0]) if self.shares else 0
        freq = [0.0] * n_experts
        active = sum(1 for u in self.updates if u > 0)
        if active == 0:
            return freq
        for l, row in enumerate(self.shares):
            if self.updates[l] == 0:
                continue
            for e, s in enumerate(row):
                freq[e] += s / active
        return freq

    def merge(self, other):
        for l in range(len(self.shares)):
            a, b = self.updates[l], other.updates[l]
            if b == 0:
                continue
            if a == 0:
                self.shares[l] = list(other.shares[l])
            else:
                wa, wb = a / (a + b), b / (a + b)
                self.shares[l] = [
                    wa * x + wb * y for x, y in zip(self.shares[l], other.shares[l])
                ]
            self.updates[l] = a + b


# ------------------------------------------------------ pinned constants


def test_ewma_pinned_constants_match_rust_unit_test():
    # the exact scenario rust pins in ewma_matches_python_mirror_constants:
    # alpha 0.25, seed [3,1]/4 then fold [1,3]/4. Every operand is a
    # dyadic rational, so the result is exact in binary on both sides.
    t = TrafficMirror(1, 2, alpha=0.25)
    t.update(0, [3, 1])
    assert t.shares[0] == [0.75, 0.25]
    t.update(0, [1, 3])
    assert t.shares[0] == [0.625, 0.375]
    assert t.updates[0] == 2


def test_first_update_seeds_directly_and_zero_total_is_noop():
    t = TrafficMirror(2, 4)
    t.update(0, [3, 1, 0, 0])
    assert t.shares[0] == [0.75, 0.25, 0.0, 0.0]
    assert t.updates == [1, 0]
    before = list(t.shares[0])
    t.update(0, [0, 0, 0, 0])
    assert t.shares[0] == before and t.updates[0] == 1


# ------------------------------------------------------------ invariants


def test_layer_shares_sum_to_one_under_fuzzed_streams():
    rng = random.Random(0x7AFF1C)
    for _ in range(200):
        n_experts = rng.randint(1, 8)
        alpha = 0.05 + 0.9 * rng.random()
        t = TrafficMirror(1, n_experts, alpha=alpha)
        updated = False
        for _ in range(rng.randint(1, 20)):
            counts = [rng.randrange(5) for _ in range(n_experts)]
            updated |= sum(counts) > 0
            t.update(0, counts)
        if updated:
            assert abs(sum(t.shares[0]) - 1.0) < 1e-9
            assert abs(sum(t.frequency()) - 1.0) < 1e-9


def test_frequency_pools_updated_layers_only():
    t = TrafficMirror(3, 2)
    t.update(0, [1, 0])
    t.update(2, [0, 1])
    # layer 1 never updated: mean over layers 0 and 2 only
    assert t.frequency() == [0.5, 0.5]
    assert TrafficMirror(2, 2).frequency() == [0.0, 0.0]


def test_ewma_converges_to_a_steady_distribution():
    # feeding the same skewed batch forever must converge on its share
    t = TrafficMirror(1, 4)
    for _ in range(200):
        t.update(0, [5, 2, 2, 1])
    want = [0.5, 0.2, 0.2, 0.1]
    assert all(abs(s - w) < 1e-9 for s, w in zip(t.shares[0], want))


# ----------------------------------------------------------------- merge


def test_merge_is_update_count_weighted():
    # rust's merge_is_update_count_weighted, exactly
    a = TrafficMirror(1, 2, alpha=1.0)
    b = TrafficMirror(1, 2, alpha=1.0)
    a.update(0, [1, 0])
    b.update(0, [0, 1])
    b.update(0, [0, 1])
    a.merge(b)
    assert a.shares[0] == [1.0 / 3.0, 2.0 / 3.0]
    assert a.updates[0] == 3


def test_merge_preserves_sum_and_adds_updates_fuzzed():
    rng = random.Random(8)
    for _ in range(100):
        n = rng.randint(1, 6)
        a, b = TrafficMirror(1, n), TrafficMirror(1, n)
        for _ in range(rng.randint(1, 6)):
            a.update(0, [1 + rng.randrange(4) for _ in range(n)])
        for _ in range(rng.randint(1, 6)):
            b.update(0, [1 + rng.randrange(4) for _ in range(n)])
        ua, ub = a.updates[0], b.updates[0]
        a.merge(b)
        assert a.updates[0] == ua + ub
        assert abs(sum(a.shares[0]) - 1.0) < 1e-9
