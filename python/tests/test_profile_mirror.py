"""Device-profile mirror tests (issue 7 satellite).

Fuzzes the Python port of the selection-predictiveness scorer against
the checked-in fixtures the Rust side consumes (≥ 200 cases, bit-exact)
and re-derives the golden per-profile sentinel deviations to pin the
fixture to the mirror that generated it. Pure numpy — no jax, no
artifacts.
"""

import json
import math
import os
import random

import numpy as np
import pytest

import mirror_profile as mp

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


def load(name):
    with open(os.path.join(FIXTURES, name)) as f:
        return json.load(f)


# ------------------------------------------------------- spearman scorer


def test_spearman_fuzz_fixture_is_reproducible():
    # every dumped rho must recompute bit-for-bit: the JSON round-trip
    # (shortest repr) and the scorer itself are both exact
    fx = load("spearman_fuzz.json")
    assert len(fx["cases"]) >= 200
    for i, case in enumerate(fx["cases"]):
        rho = mp.spearman(case["xs"], case["ys"])
        assert rho == case["rho"], f"case {i}"
        assert -1.0 - 1e-12 <= rho <= 1.0 + 1e-12


def test_spearman_rank_semantics():
    # monotone transforms preserve rank: rho is exactly ±1
    xs = [1.0, 2.0, 3.0, 4.0, 5.0]
    ys = [math.exp(x) for x in xs]
    assert mp.spearman(xs, ys) == pytest.approx(1.0, abs=1e-12)
    assert mp.spearman(xs, ys[::-1]) == pytest.approx(-1.0, abs=1e-12)
    # constant input: ties rank by index (stable sort), so the ranks
    # are 0..n-1 and correlate perfectly with an increasing ys — the
    # documented (if surprising) Rust semantics the mirror must share
    assert mp.spearman([1.0, 1.0, 1.0], [1.0, 2.0, 3.0]) == pytest.approx(1.0, abs=1e-12)
    # fewer than two points → 0 by convention (matches Rust pearson)
    assert mp.spearman([2.0], [3.0]) == 0.0


def test_spearman_ties_break_by_index():
    # Rust ranks() uses a stable sort (ties keep index order); the
    # mirror must agree on inputs with exact duplicates
    rng = random.Random(7)
    for _ in range(200):
        n = rng.randint(2, 20)
        xs = [float(rng.randint(0, 4)) for _ in range(n)]
        ys = [float(rng.randint(0, 4)) for _ in range(n)]
        rho = mp.spearman(xs, ys)
        assert -1.0 - 1e-12 <= rho <= 1.0 + 1e-12
        # rank vectors are permutations of 0..n-1 regardless of ties
        assert sorted(mp.ranks(xs)) == [float(i) for i in range(n)]


# ------------------------------------------------------- golden fixture


def test_golden_fixture_matches_mirror():
    # the checked-in deviations must re-derive from the mirror — guards
    # against the fixture and generator drifting apart
    fx = load("profile_golden.json")
    d, m, rows, seed = fx["d"], fx["m"], fx["rows"], fx["seed"]
    clock = mp.Clock(
        elapsed_tokens=fx["elapsed_tokens"], birth_tokens=0, cycle=fx["elapsed_tokens"]
    )
    rng = mp.Prng(42)

    def draw(length):
        return np.array(
            [rng.gaussian_f32() * np.float32(0.3) for _ in range(length)], np.float32
        )

    experts = [
        {"up": draw(d * m), "gate": draw(d * m), "down": draw(m * d)}
        for _ in range(fx["experts"])
    ]
    x = mp.sentinel(rows, d, seed)
    names = [p["profile"] for p in fx["profiles"]]
    assert names == ["ideal", "pcm-drift", "reram-noisy", "adc-limited", "worst-case"]
    for prof in fx["profiles"]:
        models = mp.preset(prof["profile"])
        for e, host in enumerate(experts):
            want = mp.gated_mlp(x, host["up"], host["gate"], host["down"], rows, d, m)
            up, gate, down = host["up"].copy(), host["gate"].copy(), host["down"].copy()
            mp.perturb_matrix(models, up, d, m, mp.Site(0, e, 0), clock)
            mp.perturb_matrix(models, gate, d, m, mp.Site(0, e, 1), clock)
            mp.perturb_matrix(models, down, m, d, mp.Site(0, e, 2), clock)
            got = mp.probe_deviation(mp.gated_mlp(x, up, gate, down, rows, d, m), want)
            assert got == pytest.approx(prof["deviations"][e], rel=1e-6, abs=1e-12), (
                prof["profile"],
                e,
            )
    ideal = fx["profiles"][0]["deviations"]
    assert all(v == 0.0 for v in ideal), "ideal profile must probe exactly clean"


# ------------------------------------------------ model property mirrors


def test_models_are_seed_deterministic():
    rng = random.Random(11)
    for _ in range(20):
        d, n = rng.randint(1, 12), rng.randint(1, 12)
        w0 = np.array([rng.gauss(0, 0.3) for _ in range(d * n)], np.float32)
        site = mp.Site(rng.randrange(4), rng.randrange(8), rng.randrange(3))
        clock = mp.Clock(rng.randrange(1 << 16), rng.randrange(1 << 16), rng.randrange(1 << 16))
        for model in (
            mp.ReadNoise(sigma=0.1, tile=4, seed=5),
            mp.ProgrammingError(scale=1.0, tile=4, seed=5),
        ):
            a, b = w0.copy(), w0.copy()
            model.perturb(a, d, n, site, clock)
            model.perturb(b, d, n, site, clock)
            assert np.array_equal(a, b)
            assert not np.array_equal(a, w0)


def test_adc_clip_bounds_and_ir_drop_monotone():
    w = np.array([-2.0, -0.4, 0.1, 3.0], np.float32)
    clip = mp.AdcClip(fsr=0.5, relative=False)
    clip.perturb(w, 2, 2, mp.Site(), mp.Clock())
    assert np.all(np.abs(w) <= np.float32(0.5))

    d, n = 6, 3
    ones = np.ones(d * n, np.float32)
    drop = mp.IrDrop(strength=0.4)
    drop.perturb(ones, d, n, mp.Site(), mp.Clock())
    for c in range(n):
        col = [float(ones[r * n + c]) for r in range(d)]
        assert all(b <= a + 1e-7 for a, b in zip(col, col[1:]))
        assert all(v >= 0.0 for v in col)


def test_predictiveness_sign_convention():
    maxnn = [1.0, 2.0, 3.0, 4.0]
    assert mp.selection_predictiveness(maxnn, [0.1, 0.2, 0.3, 0.4]) == pytest.approx(
        1.0, abs=1e-12
    )
    assert mp.selection_predictiveness(maxnn, [0.4, 0.3, 0.2, 0.1]) == pytest.approx(
        -1.0, abs=1e-12
    )
