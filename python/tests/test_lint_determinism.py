"""The determinism lint must catch seeded violations and stay green on
the real tree.

Runs ``scripts/lint_determinism.py`` as a subprocess (the same way CI
invokes it) against both the actual repository and synthetic trees with
planted nondeterminism, covering: every rule fires, the ``lint:allow``
escape hatch works, the baseline suppresses only what it lists, the
test-region heuristic skips ``#[cfg(test)]`` code, and ``--mirrors``
detects Rust↔Python constant drift.
"""

import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent.parent
SCRIPT = REPO / "scripts" / "lint_determinism.py"


def run_lint(*args):
    return subprocess.run(
        [sys.executable, str(SCRIPT), *args],
        capture_output=True,
        text=True,
        check=False,
    )


def plant(root, rel, text):
    path = root / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text, encoding="utf-8")


def empty_baseline(root):
    plant(root, "scripts/lint_determinism_baseline.json", "[]\n")


def test_real_tree_is_clean():
    res = run_lint()
    assert res.returncode == 0, res.stdout + res.stderr


def test_real_tree_mirrors_in_sync():
    res = run_lint("--mirrors")
    assert res.returncode == 0, res.stdout + res.stderr
    assert "in sync" in res.stdout


def test_seeded_hash_iter_violation_fails(tmp_path):
    empty_baseline(tmp_path)
    plant(
        tmp_path,
        "rust/src/moe/router.rs",
        "use std::collections::HashMap;\n",
    )
    res = run_lint("--root", str(tmp_path))
    assert res.returncode == 1, res.stdout
    assert "[hash-iter]" in res.stdout
    assert "rust/src/moe/router.rs:1" in res.stdout


def test_hash_outside_planning_paths_is_fine(tmp_path):
    empty_baseline(tmp_path)
    plant(
        tmp_path,
        "rust/src/util/cache.rs",
        "use std::collections::HashMap;\n",
    )
    res = run_lint("--root", str(tmp_path))
    assert res.returncode == 0, res.stdout


def test_wallclock_respects_whitelist(tmp_path):
    empty_baseline(tmp_path)
    plant(tmp_path, "rust/src/moe/router.rs", "let t0 = Instant::now();\n")
    plant(tmp_path, "rust/src/bench.rs", "let t0 = Instant::now();\n")
    res = run_lint("--root", str(tmp_path))
    assert res.returncode == 1, res.stdout
    assert "[wallclock]" in res.stdout
    assert "moe/router.rs" in res.stdout
    assert "bench.rs" not in res.stdout


def test_extern_rng_and_float_reduce_fire(tmp_path):
    empty_baseline(tmp_path)
    plant(
        tmp_path,
        "rust/src/util/noise.rs",
        "let x = thread_rng().gen::<f32>();\n"
        "let s = v.iter().sum::<f32>();\n",
    )
    res = run_lint("--root", str(tmp_path))
    assert res.returncode == 1, res.stdout
    assert "[extern-rng]" in res.stdout
    assert "[float-reduce]" in res.stdout


def test_lint_allow_escape_hatch(tmp_path):
    empty_baseline(tmp_path)
    plant(
        tmp_path,
        "rust/src/moe/router.rs",
        "// sound: map is drained sorted two lines down\n"
        "use std::collections::HashMap; // lint:allow(hash-iter)\n",
    )
    res = run_lint("--root", str(tmp_path))
    assert res.returncode == 0, res.stdout


def test_cfg_test_region_is_skipped(tmp_path):
    empty_baseline(tmp_path)
    plant(
        tmp_path,
        "rust/src/moe/router.rs",
        "pub fn route() {}\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    use std::collections::HashMap;\n"
        "    fn timing() { let t = Instant::now(); }\n"
        "}\n",
    )
    res = run_lint("--root", str(tmp_path))
    assert res.returncode == 0, res.stdout


def test_comment_mentions_do_not_fire(tmp_path):
    empty_baseline(tmp_path)
    plant(
        tmp_path,
        "rust/src/moe/router.rs",
        "// a HashMap would be wrong here, so we use a Vec\n",
    )
    res = run_lint("--root", str(tmp_path))
    assert res.returncode == 0, res.stdout


def test_update_baseline_then_clean(tmp_path):
    empty_baseline(tmp_path)
    plant(
        tmp_path,
        "rust/src/moe/router.rs",
        "use std::collections::HashMap;\n",
    )
    assert run_lint("--root", str(tmp_path)).returncode == 1
    res = run_lint("--root", str(tmp_path), "--update-baseline")
    assert res.returncode == 0, res.stdout
    baseline = json.loads(
        (tmp_path / "scripts/lint_determinism_baseline.json").read_text()
    )
    assert len(baseline) == 1
    assert baseline[0]["rule"] == "hash-iter"
    # baselined finding no longer fails; a *new* one still does
    assert run_lint("--root", str(tmp_path)).returncode == 0
    plant(
        tmp_path,
        "rust/src/coordinator/fresh.rs",
        "use std::collections::HashSet;\n",
    )
    res = run_lint("--root", str(tmp_path))
    assert res.returncode == 1, res.stdout
    assert "fresh.rs" in res.stdout


MIRROR_RUST_TRAFFIC = "pub const DEFAULT_TRAFFIC_ALPHA: f64 = 0.2;\n"
MIRROR_RUST_CALIB = (
    "            min_scale: 0.25,\n"
    "            max_scale: 4.0,\n"
    "            max_offset: 4.0,\n"
)
MIRROR_RUST_METRICS = "    counts: [u64; 32],\n"
MIRROR_PY_TRAFFIC = "DEFAULT_ALPHA = 0.2\n"
MIRROR_PY_CALIB = "MIN_SCALE = 0.25\nMAX_SCALE = 4.0\nMAX_OFFSET = 4.0\n"
MIRROR_PY_METRICS = "HISTOGRAM_BUCKETS = 32\n"


def plant_mirror_tree(root):
    plant(root, "rust/src/moe/traffic.rs", MIRROR_RUST_TRAFFIC)
    plant(root, "rust/src/moe/calibrate.rs", MIRROR_RUST_CALIB)
    plant(root, "rust/src/coordinator/metrics.rs", MIRROR_RUST_METRICS)
    plant(root, "python/tests/test_traffic_mirror.py", MIRROR_PY_TRAFFIC)
    plant(root, "python/tests/test_calibrate_mirror.py", MIRROR_PY_CALIB)
    plant(root, "python/tests/test_metrics_mirror.py", MIRROR_PY_METRICS)


def test_mirrors_pass_on_matching_tree(tmp_path):
    plant_mirror_tree(tmp_path)
    res = run_lint("--root", str(tmp_path), "--mirrors")
    assert res.returncode == 0, res.stdout + res.stderr


def test_mirrors_detect_drift(tmp_path):
    plant_mirror_tree(tmp_path)
    plant(
        tmp_path,
        "rust/src/moe/traffic.rs",
        "pub const DEFAULT_TRAFFIC_ALPHA: f64 = 0.3;\n",
    )
    res = run_lint("--root", str(tmp_path), "--mirrors")
    assert res.returncode == 1, res.stdout
    assert "traffic-ewma-alpha" in res.stdout
    assert "MIRROR DRIFT" in res.stdout


def test_mirrors_detect_missing_pin(tmp_path):
    plant_mirror_tree(tmp_path)
    plant(tmp_path, "python/tests/test_metrics_mirror.py", "# pin removed\n")
    res = run_lint("--root", str(tmp_path), "--mirrors")
    assert res.returncode == 1, res.stdout
    assert "wait-histogram-buckets" in res.stdout
