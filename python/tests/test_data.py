"""Synthetic language + task generation tests."""

import numpy as np
import pytest

from compile import data as D


@pytest.fixture(scope="module")
def lang():
    return D.Language(vocab=512, seed=1234)


def test_zipf_distribution_is_heavy_tailed(lang):
    p = lang.zipf_p
    assert abs(p.sum() - 1.0) < 1e-12
    # head words much more likely than tail
    assert p[0] > 50 * p[-1]


def test_successor_table_is_permutation(lang):
    s = np.sort(lang.succ)
    assert (s == np.arange(lang.n_words)).all()


def test_chain_follows_successors(lang):
    c = lang.chain(5, 4)
    assert c[0] == lang.word(5)
    assert c[1] == lang.word(int(lang.succ[5]))


def test_rows_shape_and_padding(lang):
    rng = np.random.default_rng(0)
    rows = D.make_rows(lang, rng, 50, 32)
    assert rows.shape == (50, 32)
    assert (rows[:, 0] == D.BOS).all()
    # PAD only as suffix
    for r in rows:
        nz = np.nonzero(r == D.PAD)[0]
        if len(nz):
            assert (r[nz[0]:] == D.PAD).all()


def test_rows_to_batch_masks():
    rows = np.array([[1, 10, 11, 0, 0]], np.int32)
    tk, tg, mk = D.rows_to_batch(rows)
    assert (tg[0, :2] == [10, 11]).all()
    assert mk[0].sum() == 2.0


def test_generate_all_deterministic():
    _, t1, c1, tasks1 = D.generate_all(512, 32, 100, 16, 8, seed=7)
    _, t2, c2, tasks2 = D.generate_all(512, 32, 100, 16, 8, seed=7)
    assert (t1 == t2).all() and (c1 == c2).all()
    assert tasks1[0]["items"] == tasks2[0]["items"]
    _, t3, _, _ = D.generate_all(512, 32, 100, 16, 8, seed=8)
    assert not (t1 == t3).all()


@pytest.mark.parametrize("name,fn", D.TASKS)
def test_task_items_well_formed(lang, name, fn):
    rng = np.random.default_rng(42)
    task = D.make_task(lang, rng, name, fn, 16, 32)
    assert task["name"] == name
    nc = task["n_choices"]
    assert nc in (2, 4)
    golds = []
    for item in task["items"]:
        assert len(item["choices"]) == nc
        assert 0 <= item["gold"] < nc
        # all tokens in range
        for t in item["ctx"]:
            assert 0 <= t < 512
        for c in item["choices"]:
            assert len(c) >= 1
            for t in c:
                assert 0 <= t < 512
        # fits the sequence length
        longest = max(len(c) for c in item["choices"])
        assert len(item["ctx"]) + longest <= 32
        golds.append(item["gold"])
    # gold positions shuffled (not all identical)
    assert len(set(golds)) > 1


def test_task_gold_choices_are_correct_continuations(lang):
    """The gold chain continuation must actually follow the grammar."""
    rng = np.random.default_rng(3)
    task = D.make_task(lang, rng, "syn-hella", D.task_hella, 8, 32)
    for item in task["items"]:
        ctx = item["ctx"]
        gold = item["choices"][item["gold"]]
        last = ctx[-1] - D.WORD0
        want = lang.chain(int(lang.succ[last]), len(gold))
        assert gold == want


def test_mathqa_answers_correct(lang):
    rng = np.random.default_rng(4)
    task = D.make_task(lang, rng, "syn-mathqa", D.task_mathqa, 32, 32)
    for item in task["items"]:
        ctx = item["ctx"]
        a = ctx[2] - D.DIGIT0
        op = ctx[3]
        b = ctx[4] - D.DIGIT0
        want = (a + b) % 10 if op == D.OP_PLUS else (a * b) % 10
        gold_tok = item["choices"][item["gold"]][0]
        assert gold_tok == D.DIGIT0 + want


def test_token_frequencies_zipfian():
    _, rows, _, _ = D.generate_all(512, 32, 500, 16, 4, seed=1)
    freq = D.token_frequencies(rows, 512)
    words = freq[D.WORD0:]
    # head of the Zipf word range is far denser than the tail
    head_rate = words[:20].mean()
    tail_rate = words[-200:].mean()
    assert head_rate > 5 * tail_rate, (head_rate, tail_rate)
