"""Router-calibration fit mirror tests (issue 9 satellite).

Pure-python port of ``rust/src/moe/calibrate.rs`` — the least-squares
affine fit ``want ~= scale * got + offset``, the relative-l2 residual,
the trust-region clamp, and the acceptance ladder (clamped residual must
not exceed the raw deviation and must fall under the gate) — fuzzed for
the invariants the Rust proptest asserts and pinned to the exact binary
constants the Rust unit test ``fit_matches_python_mirror_constants``
asserts. No numpy, no artifacts.
"""

import math
import random

# rust: CalibrationOptions::default() trust region
MIN_SCALE = 0.25
MAX_SCALE = 4.0
MAX_OFFSET = 4.0
# rust: least_squares_fit / fit_residual degeneracy guards
VAR_EPS = 1e-12
DEN_EPS = 1e-24


def least_squares_fit(got, want):
    """Line-for-line mirror of ``calibrate::least_squares_fit``."""
    n = min(len(got), len(want))
    if n == 0:
        return (1.0, 0.0)
    sg = sw = sgg = sgw = 0.0
    for g, w in zip(got[:n], want[:n]):
        sg += g
        sw += w
        sgg += g * g
        sgw += g * w
    var = sgg - sg * sg / n
    if not var > VAR_EPS:  # mirrors rust's NaN-rejecting `!(var > eps)`
        return (1.0, 0.0)
    scale = (sgw - sg * sw / n) / var
    offset = (sw - scale * sg) / n
    return (scale, offset)


def fit_residual(got, want, scale, offset):
    """Line-for-line mirror of ``calibrate::fit_residual``."""
    num = den = 0.0
    for g, w in zip(got, want):
        a = g * scale + offset
        num += (a - w) * (a - w)
        den += w * w
    return math.sqrt(num / max(den, DEN_EPS))


def clamp(scale, offset):
    """Mirror of ``CalibrationOptions::clamp`` at the default region."""
    return (
        min(max(scale, MIN_SCALE), MAX_SCALE),
        min(max(offset, -MAX_OFFSET), MAX_OFFSET),
    )


def fit(got, want, gate):
    """Mirror of ``RouterCalibration::fit``'s acceptance ladder.

    Returns ``(accepted, scale, offset, raw, residual)`` where a
    rejected fit serves the identity at its raw deviation.
    """
    raw = fit_residual(got, want, 1.0, 0.0)
    scale, offset = clamp(*least_squares_fit(got, want))
    residual = fit_residual(got, want, scale, offset)
    accepted = (
        residual <= raw
        and residual <= gate
        and (scale != 1.0 or offset != 0.0)
    )
    if accepted:
        return (True, scale, offset, raw, residual)
    return (False, 1.0, 0.0, raw, raw)


# ------------------------------------------------------ pinned constants


def test_fit_pinned_constants_match_rust_unit_test():
    # the exact scenario rust pins in fit_matches_python_mirror_constants:
    # got = [1,2,3,4], want = 2*got + 0.5. Every operand is a dyadic
    # rational, so the fit is exact in binary on both sides.
    got = [1.0, 2.0, 3.0, 4.0]
    want = [2.5, 4.5, 6.5, 8.5]
    scale, offset = least_squares_fit(got, want)
    assert scale == 2.0
    assert offset == 0.5
    assert fit_residual(got, want, scale, offset) == 0.0
    assert fit_residual(got, want, 1.0, 0.0) > 0.0


def test_degenerate_fits_return_identity():
    # rust's degenerate_fits_return_identity, exactly
    assert least_squares_fit([], []) == (1.0, 0.0)
    assert least_squares_fit([0.5] * 6, [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]) == (
        1.0,
        0.0,
    )


def test_trust_region_clamps_scale_and_offset():
    # true scale 8 and offset 6 both exceed the default region
    got = [1.0, 2.0, 3.0, 4.0]
    want = [8.0 * g + 6.0 for g in got]
    assert least_squares_fit(got, want) == (8.0, 6.0)
    assert clamp(8.0, 6.0) == (MAX_SCALE, MAX_OFFSET)
    assert clamp(0.01, -100.0) == (MIN_SCALE, -MAX_OFFSET)


# ------------------------------------------------------------ invariants


def test_unclamped_optimum_never_exceeds_raw_deviation():
    # the affine family contains the identity, so the (unclamped)
    # least-squares optimum can never serve a worse residual than raw —
    # only the trust-region clamp can break this, which is exactly why
    # the rust acceptance ladder re-checks `residual <= raw` post-clamp.
    rng = random.Random(0xCA11B)
    for _ in range(100):
        want = [rng.gauss(0.0, 1.0) for _ in range(rng.randint(2, 16))]
        got = [0.7 * w + 0.05 * rng.gauss(0.0, 1.0) for w in want]
        raw = fit_residual(got, want, 1.0, 0.0)
        assert raw >= 0.0 and math.isfinite(raw)
        scale, offset = least_squares_fit(got, want)
        assert fit_residual(got, want, scale, offset) <= raw + 1e-12


def test_fit_never_worsens_served_residual_fuzzed():
    # the python side of rust's prop_fit_never_worsens_served_residual:
    # either the fit stands with residual <= min(raw, gate), or the slot
    # serves the identity at exactly its raw deviation.
    rng = random.Random(0x5EED9)
    accepted_some = rejected_some = False
    for _ in range(300):
        n = 2 + rng.randrange(14)
        want = [rng.gauss(0.0, 1.0) for _ in range(n)]
        f = 0.2 + 0.8 * rng.random()
        noise = 0.2 * rng.random()
        got = [f * w + noise * rng.gauss(0.0, 1.0) for w in want]
        gate = 0.5 * rng.random()
        ok, scale, offset, raw, residual = fit(got, want, gate)
        if ok:
            accepted_some = True
            assert residual <= raw + 1e-12
            assert residual <= gate + 1e-12
            assert MIN_SCALE <= scale <= MAX_SCALE
            assert abs(offset) <= MAX_OFFSET
        else:
            rejected_some = True
            assert (scale, offset) == (1.0, 0.0)
            assert residual == raw
    assert accepted_some and rejected_some  # the fuzz exercises both arms


def test_pure_decay_is_fully_absorbed_and_raw_grows():
    # multiplicative decay (the drift law's local shape) is exactly
    # affine-correctable: the fit must absorb ~all of it while the raw
    # deviation grows monotonically with decay depth.
    want = [0.8, -1.2, 2.0, 0.4, -0.6, 1.6]
    last_raw = 0.0
    for f in (0.9, 0.7, 0.5):
        got = [f * w for w in want]
        ok, scale, _offset, raw, residual = fit(got, want, 0.05)
        assert ok
        assert raw > last_raw
        assert residual < 1e-9
        assert abs(scale - 1.0 / f) < 1e-9
        last_raw = raw


def test_impossible_gate_rejects_and_serves_raw():
    # mirrors the rust rejected_fit_resets_slot_to_identity refit: the
    # perturbed pair is non-affine, so no fit reaches residual 0.0 and
    # the 0.0 gate rejects (an exactly-affine pair would be fitted to
    # 0.0 and pass even this gate — which is correct, and why the
    # perturbation is there)
    got = [0.4, -0.6, 1.0, 0.2]
    want = [0.5 * g for g in got]
    ok, *_ = fit(got, want, 0.0)
    assert ok  # exactly affine: residual 0.0 passes even a 0.0 gate
    want[0] += 0.25
    ok, scale, offset, raw, residual = fit(got, want, 0.0)
    assert not ok and (scale, offset) == (1.0, 0.0) and residual == raw
    assert raw > 0.0
