"""L2 model tests: shapes, flag semantics, training dynamics, ABI."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as M
from compile.configs import CONFIGS, ModelConfig

TINY = ModelConfig(
    name="tiny", vocab=64, seq_len=12, d_model=16, n_heads=2, n_layers=2,
    n_experts=4, top_k=2, d_expert=8, batch=4, train_steps=2,
)
TINY_DS = ModelConfig(
    name="tiny_ds", vocab=64, seq_len=12, d_model=16, n_heads=2, n_layers=2,
    n_experts=4, top_k=2, d_expert=8, d_shared=6, dense_first_layer=True,
    d_dense_ffn=20, batch=4, train_steps=2, seed=1,
)


def make_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(1, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    targets = rng.integers(1, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
    mask = np.ones((cfg.batch, cfg.seq_len), np.float32)
    return jnp.asarray(tokens), jnp.asarray(targets), jnp.asarray(mask)


@pytest.mark.parametrize("cfg", [TINY, TINY_DS], ids=["olmoe-style", "dsmoe-style"])
def test_param_specs_and_init_consistent(cfg):
    specs = M.param_specs(cfg)
    params = M.init_params(cfg)
    assert len(specs) == len(params)
    for (name, shape), arr in zip(specs, params):
        assert arr.shape == tuple(shape), name
    # names unique
    names = [n for n, _ in specs]
    assert len(names) == len(set(names))


@pytest.mark.parametrize("cfg", [TINY, TINY_DS], ids=["olmoe-style", "dsmoe-style"])
def test_model_fwd_shape_and_finite(cfg):
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    tk, tg, mk = make_batch(cfg)
    flags = jnp.zeros((M.flags_len(cfg),), jnp.float32)
    out = M.model_fwd(cfg, params, tk, tg, mk, flags, 8.0, 1.0)
    assert out.shape == (cfg.batch,)
    assert np.isfinite(np.asarray(out)).all()


def test_digital_flags_are_exact():
    """flags=0 must be bit-identical to a quant-free forward."""
    cfg = TINY
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    tk, tg, mk = make_batch(cfg)
    z = jnp.zeros((M.flags_len(cfg),), jnp.float32)
    a = M.model_fwd(cfg, params, tk, tg, mk, z, 8.0, 1.0)
    b = M.model_fwd(cfg, params, tk, tg, mk, z, 40.0, 2.0)  # kappa/lam unused
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_analog_flags_change_output_only_for_flagged_modules():
    cfg = TINY
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    tk, tg, mk = make_batch(cfg)
    z = np.zeros((M.flags_len(cfg),), np.float32)
    base = np.asarray(M.model_fwd(cfg, params, tk, tg, mk, jnp.asarray(z), 8.0, 1.0))
    # flag one expert analog → output changes (DAC-ADC error)
    f = z.copy()
    f[0] = 1.0
    out = np.asarray(M.model_fwd(cfg, params, tk, tg, mk, jnp.asarray(f), 8.0, 1.0))
    assert not np.allclose(out, base, atol=1e-9)
    # with very aggressive low-bit quant, the change is larger
    out4 = np.asarray(M.model_fwd(cfg, params, tk, tg, mk, jnp.asarray(f), 8.0, 1.0,
                                  bits_dac=3, bits_adc=3))
    assert np.abs(out4 - base).mean() > np.abs(out - base).mean()


def test_router_gates_topk_structure():
    cfg = TINY
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.standard_normal((10, cfg.d_model)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((cfg.d_model, cfg.n_experts)).astype(np.float32))
    gmat, probs = M.router_gates(cfg, u, w)
    g = np.asarray(gmat)
    # exactly top_k nonzero per row, gates sum to 1
    assert ((g > 0).sum(axis=1) == cfg.top_k).all()
    np.testing.assert_allclose(g.sum(axis=1), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(probs).sum(axis=1), 1.0, atol=1e-5)


def test_router_gates_match_lax_topk_selection():
    """The iterative masked-argmax must select the same experts as
    jax.lax.top_k (the XLA-0.5.1-parser-safe replacement; see model.py)."""
    cfg = TINY
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.standard_normal((32, cfg.d_model)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((cfg.d_model, cfg.n_experts)).astype(np.float32))
    gmat, _ = M.router_gates(cfg, u, w)
    scores = np.asarray(u @ w)
    _, want = jax.lax.top_k(jnp.asarray(scores), cfg.top_k)
    got = np.argsort(-np.asarray(gmat), axis=1)[:, :cfg.top_k]
    assert (np.sort(got, axis=1) == np.sort(np.asarray(want), axis=1)).all()


@pytest.mark.parametrize("cfg", [TINY, TINY_DS], ids=["olmoe-style", "dsmoe-style"])
def test_train_step_reduces_loss(cfg):
    params = [jnp.asarray(p) for p in M.init_params(cfg)]
    moms = [jnp.zeros_like(p) for p in params]
    lang = D.Language(vocab=cfg.vocab, seed=9)
    rng = np.random.default_rng(9)
    rows = D.make_rows(lang, rng, 64, cfg.seq_len)
    step = jax.jit(lambda p, m, t, y, mk, lr: M.train_step(cfg, p, m, t, y, mk, lr))
    first = None
    for i in range(30):
        idx = rng.integers(0, rows.shape[0], cfg.batch)
        tk, tg, mk = D.rows_to_batch(rows[idx])
        params, moms, nll = step(params, moms, jnp.asarray(tk), jnp.asarray(tg),
                                 jnp.asarray(mk), jnp.float32(0.1))
        if first is None:
            first = float(nll)
    assert float(nll) < first, f"{first} → {float(nll)}"


def test_flags_split_layout():
    cfg = TINY
    F = M.flags_len(cfg)
    assert F == cfg.n_layers * cfg.n_experts + 2 * cfg.n_layers + 1
    flags = jnp.arange(F, dtype=jnp.float32)
    e, a, d, lm = M.split_flags(cfg, flags)
    assert e.shape == (cfg.n_layers, cfg.n_experts)
    assert float(e[1, 2]) == cfg.n_experts + 2
    assert float(a[0]) == cfg.n_layers * cfg.n_experts
    assert float(lm) == F - 1


def test_real_configs_have_positive_dims():
    for cfg in CONFIGS.values():
        assert cfg.d_model % cfg.n_heads == 0
        assert 0 < cfg.top_k <= cfg.n_experts
        specs = M.param_specs(cfg)
        assert specs[0][0] == "embed"
        assert specs[-1][0] == "lm_head"
