"""L1 correctness: the Pallas AIMC crossbar kernel vs the pure-jnp oracle.

This is the core correctness signal for the analog compute path — the
serving engine's analog expert FFN executes exactly this kernel (lowered
into expert_ffn_analog.hlo.txt), so kernel == ref means serving == eval.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aimc_mvm as K
from compile.kernels import ref


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@pytest.mark.parametrize("t,d,n", [(1, 8, 8), (8, 48, 64), (4, 64, 48), (16, 33, 17)])
@pytest.mark.parametrize("bits", [4, 8])
def test_kernel_matches_ref_single_tile(t, d, n, bits):
    x = rand((t, d), 1)
    w = rand((d, n), 2, 0.1)
    r = ref.aimc_mvm_ref(jnp.asarray(x), jnp.asarray(w), 2.5, 1.0, bits, bits)
    k = K.aimc_mvm(jnp.asarray(x), jnp.asarray(w), 2.5, 1.0, bits, bits)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r), atol=1e-6)


@pytest.mark.parametrize("t,d,n,tile", [
    (2, 600, 300, 512),   # ragged rows
    (2, 300, 600, 512),   # ragged cols
    (3, 700, 700, 512),   # both ragged
    (2, 128, 96, 32),     # many small tiles
])
def test_kernel_matches_ref_multi_tile(t, d, n, tile):
    x = rand((t, d), 3)
    w = rand((d, n), 4, 0.05)
    r = ref.aimc_mvm_ref(jnp.asarray(x), jnp.asarray(w), 3.0, 1.2, tile=tile)
    k = K.aimc_mvm(jnp.asarray(x), jnp.asarray(w), 3.0, 1.2, tile=tile)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r), atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 8),
    d=st.integers(2, 96),
    n=st.integers(2, 96),
    beta=st.floats(0.5, 8.0),
    lam=st.floats(0.5, 2.5),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_hypothesis(t, d, n, beta, lam, seed):
    """Property: kernel == oracle across random shapes and quant ranges."""
    x = rand((t, d), seed)
    w = rand((d, n), seed + 1, 0.1)
    r = ref.aimc_mvm_ref(jnp.asarray(x), jnp.asarray(w), beta, lam)
    k = K.aimc_mvm(jnp.asarray(x), jnp.asarray(w), beta, lam)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r), atol=1e-5)


def test_gated_ffn_analog_matches_ref():
    x = rand((8, 48), 5)
    wu, wg = rand((48, 64), 6, 0.1), rand((48, 64), 7, 0.1)
    wd = rand((64, 48), 8, 0.1)
    beta_up = 8.0 * float(np.std(x)) + 1e-6
    up = ref.aimc_mvm_ref(jnp.asarray(x), jnp.asarray(wu), beta_up, 1.0)
    gate = ref.aimc_mvm_ref(jnp.asarray(x), jnp.asarray(wg), beta_up, 1.0)
    act = np.asarray(ref.silu(up) * gate)
    beta_dn = 8.0 * float(np.std(act)) + 1e-6
    want = ref.aimc_mvm_ref(jnp.asarray(act), jnp.asarray(wd), beta_dn, 1.0)
    from compile.model import expert_ffn_analog
    got = expert_ffn_analog(jnp.asarray(x), jnp.asarray(wu), jnp.asarray(wg),
                            jnp.asarray(wd), 8.0, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------------------
# quantization semantics (eqs 4-5)
# ---------------------------------------------------------------------------

def test_dac_quant_clamps_and_rounds():
    x = jnp.asarray([0.0, 0.5, 5.0, -5.0], jnp.float32)
    q = np.asarray(ref.dac_quant(x, 1.0, 8))
    assert q[0] == 0.0
    assert abs(q[1] - round(0.5 * 127) / 127) < 1e-7
    assert q[2] == 1.0 and q[3] == -1.0


def test_dac_error_bounded():
    rng = np.random.default_rng(0)
    x = rng.uniform(-2, 2, 1000).astype(np.float32)
    q = np.asarray(ref.dac_quant(jnp.asarray(x), 2.0, 8))
    step = 2.0 / 127
    assert np.max(np.abs(q - x)) <= step / 2 + 1e-6


def test_higher_adc_bits_reduce_error():
    rng = np.random.default_rng(1)
    y = rng.standard_normal(2000).astype(np.float32)
    e8 = np.abs(np.asarray(ref.adc_quant(jnp.asarray(y), 4.0, 8)) - y).mean()
    e12 = np.abs(np.asarray(ref.adc_quant(jnp.asarray(y), 4.0, 12)) - y).mean()
    assert e12 < e8 / 8


def test_beta_out_guards_zero_columns():
    w = jnp.zeros((4, 3), jnp.float32)
    bo = np.asarray(ref.beta_out_for(w, 1.0, 1.0))
    assert (bo > 0).all()


# ---------------------------------------------------------------------------
# programming noise (eq 3) — oracle for the Rust implementation
# ---------------------------------------------------------------------------

def test_programming_sigma_branches():
    # |W| = Wmax → HI branch: (0.012 + 0.245 - 0.54 + 0.40) * Wmax
    s = ref.programming_sigma(np.array([1.0]), 1.0)
    assert abs(s[0] - 0.117) < 1e-12
    s0 = ref.programming_sigma(np.array([0.0]), 1.0)
    assert abs(s0[0] - 0.014) < 1e-12


def test_programming_sigma_nonnegative():
    w = np.linspace(0, 1, 1001)
    assert (ref.programming_sigma(w, 1.0) >= 0).all()


def test_program_weights_statistics():
    rng = np.random.default_rng(2)
    w = np.full((4000, 1), 0.5, np.float32)
    noisy = ref.program_weights_ref(w, rng, 1.0)
    sigma = ref.programming_sigma(np.array([0.5]), 0.5)[0]
    emp = np.std(noisy - w)
    assert abs(emp - sigma) / sigma < 0.08


def test_program_weights_scale_zero_is_identity():
    rng = np.random.default_rng(3)
    w = rng.standard_normal((16, 8)).astype(np.float32)
    out = ref.program_weights_ref(w, rng, 0.0)
    np.testing.assert_array_equal(out, w)
