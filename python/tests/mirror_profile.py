"""Python mirror of the Rust nonideality/profile stack (`aimc::profile`).

Integer-exact ports of the deterministic pieces — `util::Prng`
(SplitMix64-seeded xoshiro256** with Box-Muller gaussians), `fnv1a`
tile addressing, and the `util::stats` rank/Pearson/Spearman chain used
by `selection_predictiveness` — plus float32-faithful ports of every
`NonidealityModel` and the `DriftMonitor` sentinel-probe math.

The Spearman port matches Rust bit-for-bit (identical sequential
operation order on IEEE doubles); the perturbation/probe ports match to
f32 rounding (the Rust serving kernel accumulates its gated MLP in a
blocked order numpy does not replicate), which is why the golden
fixtures carry a small tolerance while the Spearman fuzz fixture
demands 1e-12.

Used by scripts/gen_profile_fixtures.py (writes the checked-in fixtures
the Rust integration tests consume) and tests/test_profile_mirror.py.
"""

import math
from dataclasses import dataclass

import numpy as np

_MASK = (1 << 64) - 1
_F64_MIN_POSITIVE = 2.2250738585072014e-308


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & _MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    return state, z ^ (z >> 31)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & _MASK


class Prng:
    """util::Prng — xoshiro256** + Box-Muller with a cached spare."""

    def __init__(self, seed):
        s = []
        sm = seed & _MASK
        for _ in range(4):
            sm, z = _splitmix64(sm)
            s.append(z)
        self.s = s
        self.spare = None

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & _MASK, 7) * 9) & _MASK
        t = (s[1] << 17) & _MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def gaussian(self):
        if self.spare is not None:
            z, self.spare = self.spare, None
            return z
        while True:
            u1 = self.uniform()
            if u1 <= _F64_MIN_POSITIVE:
                continue
            u2 = self.uniform()
            r = math.sqrt(-2.0 * math.log(u1))
            theta = 2.0 * math.pi * u2
            self.spare = r * math.sin(theta)
            return r * math.cos(theta)

    def gaussian_f32(self):
        return np.float32(self.gaussian())


def fnv1a(data):
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & _MASK
    return h


@dataclass(frozen=True)
class Site:
    layer: int = 0
    expert: int = 0
    mat: int = 0


@dataclass(frozen=True)
class Clock:
    elapsed_tokens: int = 0
    birth_tokens: int = 0
    cycle: int = 0


def _words_tag(words):
    return fnv1a(b"".join(int(w).to_bytes(8, "little") for w in words))


def tile_rng(seed, site, rt, ct, epoch):
    """profile::tile_rng — one stream per (site, tile, epoch)."""
    tag = _words_tag([site.layer, site.expert, site.mat, rt, ct, epoch])
    return Prng(seed ^ tag)


def _tiles(d, n, tile):
    tile = max(tile, 1)
    r0 = 0
    while r0 < d:
        r1 = min(r0 + tile, d)
        c0 = 0
        while c0 < n:
            c1 = min(c0 + tile, n)
            yield r0, r1, c0, c1
            c0 = c1
        r0 = r1


# ---------------------------------------------------------------- models
# Each perturb(w, d, n, site, clock) mutates a 1-D float32 numpy array of
# length d*n in place, replicating the Rust loop order and f32 casts.


@dataclass
class ReadNoise:
    sigma: float = 0.0
    conductance_dependent: bool = False
    tile: int = 512
    seed: int = 0

    def enabled(self):
        return self.sigma > 0.0

    def perturb(self, w, d, n, site, clock):
        if not self.enabled():
            return
        tile = max(self.tile, 1)
        for r0, r1, c0, c1 in _tiles(d, n, tile):
            rng = tile_rng(self.seed, site, r0 // tile, c0 // tile, clock.cycle)
            for r in range(r0, r1):
                for c in range(c0, c1):
                    v = float(w[r * n + c])
                    g = rng.gaussian()
                    s = self.sigma * abs(v) if self.conductance_dependent else self.sigma
                    w[r * n + c] = np.float32(v + g * s)


PCM_SPLIT = 0.292
PCM_COEF_HI = [0.012, 0.245, -0.54, 0.40]
PCM_COEF_LO = [0.014, 0.224, -0.72, 0.952]


def programming_sigma(w, w_max):
    """program::programming_sigma — eq (3) σ for one weight."""
    w_max = max(w_max, 1e-12)
    aw = abs(w)
    c = PCM_COEF_HI if aw / w_max > PCM_SPLIT else PCM_COEF_LO
    sigma = (
        c[0] * w_max
        + c[1] * aw
        + c[2] * aw * aw / w_max
        + c[3] * aw * aw * aw / (w_max * w_max)
    )
    return max(sigma, 0.0)


@dataclass
class ProgrammingError:
    scale: float = 0.0
    tile: int = 512
    seed: int = 0

    def enabled(self):
        return self.scale > 0.0

    def perturb(self, w, d, n, site, clock):
        if not self.enabled():
            return
        tile = max(self.tile, 1)
        for r0, r1, c0, c1 in _tiles(d, n, tile):
            rng = tile_rng(self.seed, site, r0 // tile, c0 // tile, clock.birth_tokens)
            for c in range(c0, c1):
                w_max = 0.0
                for r in range(r0, r1):
                    w_max = max(w_max, abs(float(w[r * n + c])))
                if w_max <= 0.0:
                    continue
                for r in range(r0, r1):
                    v = float(w[r * n + c])
                    sigma = programming_sigma(v, w_max) * self.scale
                    w[r * n + c] = np.float32(v + rng.gaussian() * sigma)


@dataclass
class AdcClip:
    fsr: float = 0.0
    relative: bool = False

    def enabled(self):
        return self.fsr > 0.0

    def perturb(self, w, d, n, site, clock):
        if not self.enabled():
            return
        if self.relative:
            mx = np.max(np.abs(w)) if w.size else np.float32(0.0)
            bound = np.float32(self.fsr * float(mx))
        else:
            bound = np.float32(self.fsr)
        np.clip(w, -bound, bound, out=w)


@dataclass
class IrDrop:
    strength: float = 0.0
    row_weight: float = 0.5

    def enabled(self):
        return self.strength > 0.0

    def factor(self, r, c, d, n):
        rho = min(max(self.row_weight, 0.0), 1.0)
        rd = r / max(d - 1, 1)
        cd = c / max(n - 1, 1)
        return max(1.0 - self.strength * (rho * rd + (1.0 - rho) * cd), 0.0)

    def perturb(self, w, d, n, site, clock):
        if not self.enabled():
            return
        for r in range(d):
            for c in range(n):
                w[r * n + c] = np.float32(w[r * n + c] * np.float32(self.factor(r, c, d, n)))


@dataclass
class DriftModel:
    nu: float = 0.0
    nu_jitter: float = 0.0
    t0_tokens: int = 256
    tile: int = 512
    seed: int = 0

    @classmethod
    def with_nu(cls, nu, **kw):
        return cls(nu=nu, nu_jitter=nu / 10.0, **kw)

    def enabled(self):
        return self.nu > 0.0 or self.nu_jitter > 0.0

    def factor(self, nu, elapsed_tokens):
        if nu <= 0.0 or elapsed_tokens <= self.t0_tokens:
            return 1.0
        t = elapsed_tokens / max(self.t0_tokens, 1)
        return t ** (-nu)

    def tile_nu(self, layer, expert, mat, rt, ct):
        if self.nu_jitter <= 0.0:
            return max(self.nu, 0.0)
        tag = _words_tag([layer, expert, mat, rt, ct])
        rng = Prng(self.seed ^ tag)
        return max(self.nu + rng.gaussian() * self.nu_jitter, 0.0)

    def perturb(self, w, d, n, site, clock):
        if not self.enabled() or clock.elapsed_tokens <= self.t0_tokens:
            return
        tile = max(self.tile, 1)
        for r0, r1, c0, c1 in _tiles(d, n, tile):
            nu = self.tile_nu(site.layer, site.expert, site.mat, r0 // tile, c0 // tile)
            f = np.float32(self.factor(nu, clock.elapsed_tokens))
            if f != np.float32(1.0):
                for r in range(r0, r1):
                    for c in range(c0, c1):
                        w[r * n + c] = np.float32(w[r * n + c] * f)


PRESETS = {
    "ideal": lambda: [],
    "pcm-drift": lambda: [
        DriftModel.with_nu(0.3, seed=0xD01F),
        ProgrammingError(scale=0.5, seed=0x5C01),
    ],
    "reram-noisy": lambda: [
        ReadNoise(sigma=0.08, conductance_dependent=True, seed=0x2EAD),
    ],
    "adc-limited": lambda: [
        ReadNoise(sigma=0.01, conductance_dependent=False, seed=0xADC0),
        AdcClip(fsr=0.5, relative=True),
    ],
    "worst-case": lambda: [
        DriftModel.with_nu(0.4, seed=0xBAD0),
        ProgrammingError(scale=0.5, seed=0xBAD1),
        ReadNoise(sigma=0.08, conductance_dependent=True, seed=0xBAD2),
        IrDrop(strength=0.15),
        AdcClip(fsr=0.75, relative=True),
    ],
}


def preset(name):
    """DeviceProfile::preset — the model stack, in application order."""
    return PRESETS[name]()


def perturb_matrix(models, w, d, n, site, clock):
    for m in models:
        if m.enabled():
            m.perturb(w, d, n, site, clock)


# ------------------------------------------------------------ probe math


def silu(x):
    return x / (np.float32(1.0) + np.exp(-x))


def gated_mlp(x, up, gate, down, n, d, m):
    """tensor::gated_mlp — `(silu(x@up) * (x@gate)) @ down` in float32.

    numpy's matmul accumulation order differs from the Rust blocked
    kernel, so agreement is to f32 rounding, not bit-exact.
    """
    X = np.asarray(x, np.float32).reshape(n, d)
    U = X @ np.asarray(up, np.float32).reshape(d, m)
    G = X @ np.asarray(gate, np.float32).reshape(d, m)
    act = (silu(U) * G).astype(np.float32)
    return (act @ np.asarray(down, np.float32).reshape(m, d)).reshape(-1)


def sentinel(rows, d, seed):
    """DriftMonitor's cached probe input: Prng(seed ^ 0xD21F_7001)."""
    rng = Prng(seed ^ 0xD21F_7001)
    return np.array(
        [rng.gaussian_f32() * np.float32(0.5) for _ in range(rows * d)], np.float32
    )


def probe_deviation(got, want):
    """Relative ℓ2 output deviation, Rust op order (f32 diff, f64 sums)."""
    num = 0.0
    den = 0.0
    for a, b in zip(got, want):
        diff = float(np.float32(a) - np.float32(b))
        num += diff * diff
        den += float(b) ** 2
    return math.sqrt(num / max(den, 1e-24))


def col_norms(w, d, m):
    """tensor::col_norms — f64 column ℓ2 norms, row-sequential sums."""
    acc = [0.0] * m
    for r in range(d):
        for c in range(m):
            v = float(w[r * m + c])
            acc[c] += v * v
    return [math.sqrt(a) for a in acc]


def maxnn_score(up, gate, down, d, m):
    """profile::maxnn_score — product of the three max column norms."""
    def mx(w, r, c):
        best = 0.0
        for v in col_norms(w, r, c):
            best = max(best, v)
        return best

    return mx(up, d, m) * mx(gate, d, m) * mx(down, m, d)


# -------------------------------------------------- predictiveness scorer
# Bit-exact port of util::stats — sequential f64 sums, stable sorts.


def ranks(xs):
    idx = sorted(range(len(xs)), key=lambda i: xs[i])
    r = [0.0] * len(xs)
    for rank, i in enumerate(idx):
        r[i] = float(rank)
    return r


def _mean(xs):
    s = 0.0
    for x in xs:
        s += x
    return s / len(xs)


def pearson(xs, ys):
    assert len(xs) == len(ys)
    n = len(xs)
    if n < 2:
        return 0.0
    mx = _mean(xs)
    my = _mean(ys)
    num = 0.0
    dx = 0.0
    dy = 0.0
    for i in range(n):
        a = xs[i] - mx
        b = ys[i] - my
        num += a * b
        dx += a * a
        dy += b * b
    if dx <= 0.0 or dy <= 0.0:
        return 0.0
    return num / (math.sqrt(dx) * math.sqrt(dy))


def spearman(xs, ys):
    return pearson(ranks(xs), ranks(ys))


def selection_predictiveness(maxnn, degradation):
    """profile::selection_predictiveness — Spearman rank correlation."""
    return spearman(maxnn, degradation)
